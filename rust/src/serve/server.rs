//! The serving front-end: a [`FleetServer`] owns an [`AucFleet`]
//! behind a mutex, answers read queries from a **bounded pool of
//! connection workers**, and pushes sketch deltas to subscribers
//! through per-subscriber queues after every ingestion drain.
//!
//! One listener port speaks both protocols. The first byte of a
//! connection routes it: [`wire::MAGIC`]'s `0xAB` can never begin an
//! HTTP method token, so anything else is parsed as HTTP/1.1
//! (`GET`-only, keep-alive, `Content-Length`-framed JSON bodies)
//! and a `0xAB` preamble opens a length-prefixed binary session.
//!
//! **Degrade gracefully.** The acceptor feeds a bounded queue
//! (`super::limits`); when it is full the connection is shed at the
//! door with HTTP 503 / a [`wire::STATUS_BUSY`] frame instead of
//! queueing unboundedly. Every socket carries read/write timeouts,
//! every request a deadline budget once its first byte arrives, and
//! HTTP heads are capped at [`MAX_HEAD_BYTES`] (431 beyond) — so
//! half-open connects, slow-loris heads and endless-header clients
//! each cost one worker for at most one timeout.
//!
//! **Wire ≡ in-process, at an echoed seq.** Sketch-answerable reads
//! are served from the current [`PublishedView`](super::PublishedView)
//! with zero fleet-lock acquisitions (`super::publish`); only
//! `/score_histogram` — which needs raw window entries no snapshot
//! carries — takes the fleet lock. Every response echoes the view's
//! publication seq (`X-Fleet-Seq` header / an 8-byte payload prefix),
//! and `rust/tests/serve.rs` proves each wire answer bit-identical to
//! the in-process query at that seq.
//!
//! Malformed requests never panic the fleet: parameters are validated
//! at the surface ([`validate`]) and rejected with HTTP 400 or a
//! [`wire::STATUS_ERR`] frame — notably `bins=0` histograms (the
//! in-process methods assert) and non-finite `count_below` thresholds
//! (JSON cannot carry them back).

use std::io::{self, BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::Duration;

use super::limits::{is_disconnect, is_timeout, AcceptQueue, ConnTracker, Deadline, ServeLimits};
use super::publish::{seq_prefixed, Fanout, PublishedView, SubProto};
use super::{json, wire};
use crate::fleet::{AucFleet, FleetSketch};

/// Cap on one HTTP request head (request line + headers, bytes).
/// Beyond it the server answers `431 Request Header Fields Too Large`
/// and closes — a client streaming endless headers can no longer grow
/// a `String` without bound.
pub const MAX_HEAD_BYTES: usize = 8 * 1024;

/// How long the acceptor will wait for a to-be-shed connection to
/// reveal its protocol before dropping it silently. Short on purpose:
/// shedding runs on the accept thread, and a flood of half-open
/// connects must not stall admission of real ones behind it.
const SHED_WAIT: Duration = Duration::from_millis(100);

/// A query decoded from either protocol; both surfaces funnel into
/// the same answers so they cannot diverge.
enum Request {
    Snapshot,
    Aggregate,
    TopK(usize),
    CountBelow(f64),
    AucHistogram(usize),
    ScoreHistogram(usize),
    Subscribe,
}

/// Surface validation — everything that would panic or be
/// unserializable in-process is rejected here with a client error.
fn validate(req: &Request) -> Result<(), String> {
    match *req {
        Request::CountBelow(t) if !t.is_finite() => {
            Err(format!("count_below: threshold must be finite, got {t}"))
        }
        Request::AucHistogram(0) => Err("auc_histogram: bins must be >= 1".to_string()),
        Request::ScoreHistogram(0) => Err("score_histogram: bins must be >= 1".to_string()),
        _ => Ok(()),
    }
}

/// Answer one query as `(seq, JSON body)`. Sketch-answerable requests
/// read the current published view — no fleet lock once the epoch is
/// materialized; `score_histogram` needs raw window entries and takes
/// the fleet lock, reading the seq while still holding it (the epoch
/// invariant makes that seq exactly this answer's epoch).
fn answer_json(shared: &Shared, req: &Request) -> (u64, String) {
    match *req {
        Request::ScoreHistogram(b) => {
            let fleet = lock(&shared.fleet);
            let body = json::score_histogram_to_json(&fleet.score_histogram(b));
            (shared.fanout.view().seq(), body)
        }
        Request::Subscribe => unreachable!("subscribe is handled by the session loop"),
        _ => {
            let view = shared.fanout.materialized_view(&shared.fleet);
            let body = match *req {
                Request::Snapshot => json::snapshot_to_json(view.snapshot()),
                Request::Aggregate => json::aggregate_to_json(view.aggregate()),
                Request::TopK(k) => json::top_k_to_json(&view.top_k_worst(k)),
                Request::CountBelow(t) => json::count_below_to_json(t, view.count_below(t)),
                Request::AucHistogram(b) => json::auc_histogram_to_json(&view.auc_histogram(b)),
                _ => unreachable!("score_histogram and subscribe handled above"),
            };
            (view.seq(), body)
        }
    }
}

/// Binary twin of [`answer_json`] — same routing, wire codec.
fn answer_binary(shared: &Shared, req: &Request) -> (u64, Vec<u8>) {
    match *req {
        Request::ScoreHistogram(b) => {
            let fleet = lock(&shared.fleet);
            let body = wire::encode_score_histogram(&fleet.score_histogram(b));
            (shared.fanout.view().seq(), body)
        }
        Request::Subscribe => unreachable!("subscribe is handled by the session loop"),
        _ => {
            let view = shared.fanout.materialized_view(&shared.fleet);
            let body = match *req {
                Request::Snapshot => wire::encode_snapshot(view.snapshot()),
                Request::Aggregate => wire::encode_aggregate(view.aggregate()),
                Request::TopK(k) => wire::encode_top_k(&view.top_k_worst(k)),
                Request::CountBelow(t) => wire::encode_count_below(t, view.count_below(t)),
                Request::AucHistogram(b) => wire::encode_auc_histogram(&view.auc_histogram(b)),
                _ => unreachable!("score_histogram and subscribe handled above"),
            };
            (view.seq(), body)
        }
    }
}

// ---------------------------------------------------------------------
// Shared state
// ---------------------------------------------------------------------

struct Shared {
    fleet: Mutex<AucFleet>,
    fanout: Arc<Fanout>,
    queue: AcceptQueue,
    tracker: Arc<ConnTracker>,
    stop: Arc<AtomicBool>,
    limits: ServeLimits,
}

const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<Shared>();
};

/// Lock the fleet (or any serve-layer mutex), ignoring poisoning: a
/// panicking connection worker must not wedge every later request
/// (same policy as `fleet/pool.rs`).
fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

// ---------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------

/// A running serving front-end over one [`AucFleet`].
///
/// The server is `Sync`: ingestion goes through `&self`
/// ([`FleetServer::ingest_batch_at`]) while the worker pool answers
/// queries concurrently, so one thread can drive the event feed while
/// clients read. Dropping the server stops the acceptor, drains the
/// connection workers and subscriber writers (each socket op is
/// timeout-bounded and the live-connection tracker half-closes
/// whatever is still blocked), and disconnects subscribers.
pub struct FleetServer {
    shared: Arc<Shared>,
    addr: SocketAddr,
    acceptor: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl FleetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start accepting connections over `fleet`, with
    /// [`ServeLimits::default`].
    pub fn start(fleet: AucFleet, addr: &str) -> io::Result<FleetServer> {
        FleetServer::start_with(fleet, addr, ServeLimits::default())
    }

    /// [`FleetServer::start`] with explicit [`ServeLimits`].
    pub fn start_with(fleet: AucFleet, addr: &str, limits: ServeLimits) -> io::Result<FleetServer> {
        if limits.workers == 0 || limits.max_conns == 0 || limits.timeout.is_zero() {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                "serve limits must be positive (workers, max_conns, timeout)",
            ));
        }
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let baseline = fleet.sketch_state();
        let stop = Arc::new(AtomicBool::new(false));
        let shared = Arc::new(Shared {
            fleet: Mutex::new(fleet),
            fanout: Arc::new(Fanout::new(baseline, Arc::clone(&stop), limits.max_conns)),
            queue: AcceptQueue::new(limits.max_conns),
            tracker: Arc::new(ConnTracker::default()),
            stop,
            limits,
        });
        let mut workers = Vec::with_capacity(limits.workers);
        for i in 0..limits.workers {
            let worker_shared = Arc::clone(&shared);
            workers.push(
                thread::Builder::new()
                    .name(format!("fleet-serve-worker-{i}"))
                    .spawn(move || worker_loop(&worker_shared))?,
            );
        }
        let accept_shared = Arc::clone(&shared);
        let acceptor = thread::Builder::new()
            .name("fleet-serve-accept".to_string())
            .spawn(move || {
                for conn in listener.incoming() {
                    if accept_shared.stop.load(Ordering::Acquire) {
                        break;
                    }
                    let Ok(stream) = conn else { continue };
                    if let Err(stream) = accept_shared.queue.offer(stream) {
                        shed(stream, &accept_shared);
                    }
                }
            })?;
        Ok(FleetServer { shared, addr: local, acceptor: Some(acceptor), workers })
    }

    /// The bound address (with the real port when bound to `:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The limits this server enforces.
    pub fn limits(&self) -> ServeLimits {
        self.shared.limits
    }

    /// Feed a batch at the fleet's internal clock, then publish the
    /// resulting view (and the sketch delta, if any) to subscribers.
    /// Never blocks on a subscriber socket: fan-out is queue-only.
    pub fn ingest_batch(&self, batch: &[(u64, f64, bool)]) {
        let mut fleet = lock(&self.shared.fleet);
        fleet.push_batch(batch);
        // republish reads sketch_state, which waits for the drain —
        // per-drain deltas are the contract.
        self.shared.fanout.republish(&fleet);
    }

    /// Feed a batch at an explicit clock, then publish.
    pub fn ingest_batch_at(&self, batch: &[(u64, f64, bool)], at: u64) {
        let mut fleet = lock(&self.shared.fleet);
        fleet.push_batch_at(batch, at);
        self.shared.fanout.republish(&fleet);
    }

    /// Run `f` against the fleet under the serving lock — the
    /// in-process answer a wire response must be bit-identical to.
    pub fn with_fleet<R>(&self, f: impl FnOnce(&AucFleet) -> R) -> R {
        f(&lock(&self.shared.fleet))
    }

    /// Run `f` against the fleet mutably (eviction, hibernation,
    /// reconfiguration), then republish the view so reads never see
    /// pre-mutation state — with a sketch delta to subscribers if the
    /// mutation moved the sketch.
    pub fn with_fleet_mut<R>(&self, f: impl FnOnce(&mut AucFleet) -> R) -> R {
        let mut fleet = lock(&self.shared.fleet);
        let r = f(&mut fleet);
        self.shared.fanout.republish(&fleet);
        r
    }

    /// Currently attached subscribers (writers still running).
    pub fn subscriber_count(&self) -> usize {
        self.shared.fanout.subscriber_count()
    }

    /// The last published `(seq, sketch)` — what an up-to-date
    /// subscriber has reconstructed.
    pub fn last_published(&self) -> (u64, FleetSketch) {
        let v = self.shared.fanout.view();
        (v.seq(), v.sketch().clone())
    }

    /// The current [`PublishedView`], materialized — the state every
    /// sketch-answerable wire response at this seq is bit-identical
    /// to.
    pub fn published_view(&self) -> Arc<PublishedView> {
        self.shared.fanout.materialized_view(&self.shared.fleet)
    }

    /// Stop accepting, then drain everything before returning: join
    /// the acceptor, drop queued connections, half-close live ones so
    /// blocked workers and subscriber writers unblock immediately,
    /// and join them all. The drain is deadline-bounded by
    /// construction — every socket op has a timeout and every loop
    /// re-checks the stop flag — so no handler can outlive shutdown
    /// and answer afterwards. Idempotent; also runs on drop.
    pub fn shutdown(&mut self) {
        if self.shared.stop.swap(true, Ordering::AcqRel) {
            return;
        }
        // Unblock the blocking accept() with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(handle) = self.acceptor.take() {
            let _ = handle.join();
        }
        // Reset whatever was accepted but never claimed, and wake
        // every parked worker so it can observe the closed queue.
        drop(self.shared.queue.close());
        // Live connections: half-close so in-flight reads/writes
        // return now instead of after a full socket timeout.
        self.shared.tracker.shutdown_all();
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
        // Disconnect subscriber queues and join their writers.
        self.shared.fanout.shutdown();
    }
}

impl Drop for FleetServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ---------------------------------------------------------------------
// Admission: workers and shedding
// ---------------------------------------------------------------------

fn worker_loop(shared: &Arc<Shared>) {
    while let Some(conn) = shared.queue.take() {
        let token = shared.tracker.register(&conn);
        let _ = serve_connection(conn, shared);
        shared.tracker.deregister(token);
    }
}

/// Overload response on the accept thread: give the connection
/// [`SHED_WAIT`] to reveal its protocol, answer 503 / `STATUS_BUSY`,
/// drop it. Never blocks longer — admission must keep moving.
fn shed(mut stream: TcpStream, shared: &Shared) {
    if stream.set_read_timeout(Some(SHED_WAIT)).is_err()
        || stream.set_write_timeout(Some(SHED_WAIT)).is_err()
    {
        return;
    }
    let mut first = [0u8; 1];
    let Ok(n) = stream.peek(&mut first) else { return };
    if n == 0 {
        return;
    }
    let seq = shared.fanout.view().seq();
    let busy = "server busy: connection limit reached";
    if first[0] == wire::MAGIC[0] {
        let _ = wire::write_frame(
            &mut stream,
            wire::STATUS_BUSY,
            &seq_prefixed(seq, busy.as_bytes()),
        );
    } else {
        let _ = write_http(&mut stream, 503, &error_body(busy), true, seq);
    }
}

// ---------------------------------------------------------------------
// Connection handling
// ---------------------------------------------------------------------

fn serve_connection(stream: TcpStream, shared: &Arc<Shared>) -> io::Result<()> {
    stream.set_read_timeout(Some(shared.limits.timeout))?;
    stream.set_write_timeout(Some(shared.limits.timeout))?;
    let mut first = [0u8; 1];
    // The peek carries the read timeout: a half-open connect that
    // never sends a byte releases this worker after one timeout.
    let n = match stream.peek(&mut first) {
        Ok(n) => n,
        Err(e) if is_timeout(&e) || is_disconnect(&e) => return Ok(()),
        Err(e) => return Err(e),
    };
    if n == 0 {
        return Ok(()); // closed before sending anything
    }
    if first[0] == wire::MAGIC[0] {
        handle_binary(stream, shared)
    } else {
        handle_http(stream, shared)
    }
}

fn handle_binary(mut stream: TcpStream, shared: &Arc<Shared>) -> io::Result<()> {
    let mut magic = [0u8; 4];
    stream.read_exact(&mut magic)?;
    if magic != wire::MAGIC {
        let seq = shared.fanout.view().seq();
        return wire::write_frame(
            &mut stream,
            wire::STATUS_ERR,
            &seq_prefixed(seq, b"bad magic"),
        );
    }
    loop {
        if shared.stop.load(Ordering::Acquire) {
            return Ok(());
        }
        let (op, payload) = match read_request_frame(&mut stream, shared.limits.timeout)? {
            FrameOutcome::Frame(op, payload) => (op, payload),
            FrameOutcome::Closed => return Ok(()), // hangup, idle expiry, or mid-frame stall
            FrameOutcome::Oversized(len) => {
                // The unread payload makes resync impossible — reject
                // and close.
                let seq = shared.fanout.view().seq();
                let msg = format!(
                    "frame length {len} exceeds the {}-byte request cap",
                    wire::MAX_REQUEST_FRAME
                );
                return wire::write_frame(
                    &mut stream,
                    wire::STATUS_ERR,
                    &seq_prefixed(seq, msg.as_bytes()),
                );
            }
        };
        match binary_request(op, &payload) {
            Ok(Request::Subscribe) => {
                return match shared.fanout.subscribe(stream, SubProto::Binary, &shared.tracker) {
                    Ok(()) => Ok(()),
                    Err(mut stream) => {
                        let seq = shared.fanout.view().seq();
                        wire::write_frame(
                            &mut stream,
                            wire::STATUS_BUSY,
                            &seq_prefixed(seq, b"server busy: subscriber limit reached"),
                        )
                    }
                };
            }
            Ok(req) => {
                let (seq, body) = answer_binary(shared, &req);
                wire::write_frame(&mut stream, wire::STATUS_OK, &seq_prefixed(seq, &body))?;
            }
            Err(msg) => {
                let seq = shared.fanout.view().seq();
                wire::write_frame(
                    &mut stream,
                    wire::STATUS_ERR,
                    &seq_prefixed(seq, msg.as_bytes()),
                )?;
            }
        }
    }
}

fn binary_request(op: u8, payload: &[u8]) -> Result<Request, String> {
    let mut c = wire::Cursor::new(payload);
    let req = match op {
        wire::OP_SNAPSHOT => Request::Snapshot,
        wire::OP_AGGREGATE => Request::Aggregate,
        wire::OP_TOP_K => Request::TopK(c.u32()? as usize),
        wire::OP_COUNT_BELOW => Request::CountBelow(c.f64()?),
        wire::OP_AUC_HISTOGRAM => Request::AucHistogram(c.u32()? as usize),
        wire::OP_SCORE_HISTOGRAM => Request::ScoreHistogram(c.u32()? as usize),
        wire::OP_SUBSCRIBE => Request::Subscribe,
        other => return Err(format!("unknown opcode {other}")),
    };
    c.done()?;
    validate(&req)?;
    Ok(req)
}

/// One request frame read under the deadline discipline: the opcode
/// byte is the idle wait (bounded by the socket read timeout); once it
/// arrives the rest of the frame must land within one deadline budget,
/// read in chunks so a byte-trickling client cannot reset the clock.
enum FrameOutcome {
    Frame(u8, Vec<u8>),
    Closed,
    Oversized(usize),
}

fn read_request_frame(stream: &mut TcpStream, budget: Duration) -> io::Result<FrameOutcome> {
    let mut op = [0u8; 1];
    match stream.read(&mut op) {
        Ok(0) => return Ok(FrameOutcome::Closed),
        Ok(_) => {}
        Err(e) if is_timeout(&e) || is_disconnect(&e) => return Ok(FrameOutcome::Closed),
        Err(e) => return Err(e),
    }
    let deadline = Deadline::after(budget);
    let outcome = read_frame_rest(stream, op[0], &deadline);
    // Restore the idle allowance for the next request (the deadline
    // reads shrank the socket timeout).
    stream.set_read_timeout(Some(budget))?;
    outcome
}

fn read_frame_rest(
    stream: &mut TcpStream,
    op: u8,
    deadline: &Deadline,
) -> io::Result<FrameOutcome> {
    let mut head = [0u8; 4];
    if !read_full_by_deadline(stream, &mut head, deadline)? {
        return Ok(FrameOutcome::Closed);
    }
    let len = u32::from_le_bytes(head) as usize;
    if len > wire::MAX_REQUEST_FRAME {
        return Ok(FrameOutcome::Oversized(len));
    }
    let mut payload = vec![0u8; len];
    if !read_full_by_deadline(stream, &mut payload, deadline)? {
        return Ok(FrameOutcome::Closed);
    }
    Ok(FrameOutcome::Frame(op, payload))
}

/// Fill `buf` before `deadline` expires; `Ok(false)` means the peer
/// went away or ran out the clock (close quietly either way). Reads
/// chunk-at-a-time with the timeout pinned to the *remaining* budget,
/// so each arriving byte cannot restart the full socket timeout.
fn read_full_by_deadline(
    stream: &mut TcpStream,
    buf: &mut [u8],
    deadline: &Deadline,
) -> io::Result<bool> {
    let mut filled = 0;
    while filled < buf.len() {
        let Some(rem) = deadline.remaining() else { return Ok(false) };
        stream.set_read_timeout(Some(rem))?;
        match stream.read(&mut buf[filled..]) {
            Ok(0) => return Ok(false),
            Ok(n) => filled += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) if is_timeout(&e) || is_disconnect(&e) => return Ok(false),
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

// ---------------------------------------------------------------------
// HTTP
// ---------------------------------------------------------------------

enum HttpError {
    /// 400 with a message.
    Bad(String),
    /// 404 for an unknown path.
    NotFound(String),
}

/// How one attempt to read a request head ended.
enum HeadOutcome {
    Request { method: String, target: String, close: bool },
    /// Peer hung up, idled out between requests, or sent non-UTF-8
    /// garbage — close quietly.
    Closed,
    /// Head exceeded [`MAX_HEAD_BYTES`] — answer 431.
    TooLarge,
    /// Head started but did not finish within the deadline budget —
    /// answer 408.
    TimedOut,
}

fn handle_http(stream: TcpStream, shared: &Arc<Shared>) -> io::Result<()> {
    let ctl = stream.try_clone()?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    loop {
        if shared.stop.load(Ordering::Acquire) {
            return Ok(());
        }
        let (method, target, close) =
            match read_http_head(&mut reader, &ctl, shared.limits.timeout)? {
                HeadOutcome::Request { method, target, close } => (method, target, close),
                HeadOutcome::Closed => return Ok(()),
                HeadOutcome::TooLarge => {
                    let seq = shared.fanout.view().seq();
                    return write_http(
                        &mut stream,
                        431,
                        &error_body(&format!("request head exceeds {MAX_HEAD_BYTES} bytes")),
                        true,
                        seq,
                    );
                }
                HeadOutcome::TimedOut => {
                    let seq = shared.fanout.view().seq();
                    return write_http(
                        &mut stream,
                        408,
                        &error_body("request head not completed within the deadline"),
                        true,
                        seq,
                    );
                }
            };
        match http_request(&method, &target) {
            Ok(Request::Subscribe) => {
                return match shared.fanout.subscribe(stream, SubProto::Http, &shared.tracker) {
                    Ok(()) => Ok(()),
                    Err(mut stream) => {
                        let seq = shared.fanout.view().seq();
                        write_http(
                            &mut stream,
                            503,
                            &error_body("server busy: subscriber limit reached"),
                            true,
                            seq,
                        )
                    }
                };
            }
            Ok(req) => {
                let (seq, body) = answer_json(shared, &req);
                write_http(&mut stream, 200, &body, close, seq)?;
            }
            Err(HttpError::NotFound(path)) => {
                let seq = shared.fanout.view().seq();
                write_http(
                    &mut stream,
                    404,
                    &error_body(&format!("no such endpoint {path}")),
                    close,
                    seq,
                )?;
            }
            Err(HttpError::Bad(msg)) => {
                let seq = shared.fanout.view().seq();
                write_http(&mut stream, 400, &error_body(&msg), close, seq)?;
            }
        }
        if close {
            return Ok(());
        }
    }
}

enum LineError {
    TooLong,
    Io(io::Error),
}

/// `read_line` capped at `cap` bytes — the primitive that makes every
/// head read bounded even when no newline ever arrives.
fn bounded_line(
    reader: &mut BufReader<TcpStream>,
    line: &mut String,
    cap: usize,
) -> Result<usize, LineError> {
    let mut limited = reader.by_ref().take(cap as u64 + 1);
    let n = limited.read_line(line).map_err(LineError::Io)?;
    if n > cap {
        return Err(LineError::TooLong);
    }
    Ok(n)
}

/// Read one request head under the cap and deadline discipline: the
/// request line is the idle keep-alive wait (bounded by the socket
/// read timeout); once it arrives the remaining headers must land
/// within one deadline budget and [`MAX_HEAD_BYTES`] in total.
fn read_http_head(
    reader: &mut BufReader<TcpStream>,
    ctl: &TcpStream,
    budget: Duration,
) -> io::Result<HeadOutcome> {
    let mut line = String::new();
    match bounded_line(reader, &mut line, MAX_HEAD_BYTES) {
        Ok(0) => return Ok(HeadOutcome::Closed),
        Ok(_) => {}
        Err(LineError::TooLong) => return Ok(HeadOutcome::TooLarge),
        Err(LineError::Io(e)) if is_timeout(&e) || is_disconnect(&e) => {
            return Ok(HeadOutcome::Closed)
        }
        Err(LineError::Io(e)) if e.kind() == io::ErrorKind::InvalidData => {
            return Ok(HeadOutcome::Closed) // non-UTF-8 garbage preamble
        }
        Err(LineError::Io(e)) => return Err(e),
    }
    let mut used = line.len();
    let (method, target) = {
        let mut parts = line.split_whitespace();
        (parts.next().unwrap_or("").to_string(), parts.next().unwrap_or("/").to_string())
    };
    let deadline = Deadline::after(budget);
    let mut close = false;
    let outcome = loop {
        if used >= MAX_HEAD_BYTES {
            break HeadOutcome::TooLarge;
        }
        let Some(rem) = deadline.remaining() else { break HeadOutcome::TimedOut };
        ctl.set_read_timeout(Some(rem))?;
        line.clear();
        match bounded_line(reader, &mut line, MAX_HEAD_BYTES - used) {
            Ok(0) => break HeadOutcome::Closed,
            Ok(n) => used += n,
            Err(LineError::TooLong) => break HeadOutcome::TooLarge,
            Err(LineError::Io(e)) if is_timeout(&e) => break HeadOutcome::TimedOut,
            Err(LineError::Io(e))
                if is_disconnect(&e) || e.kind() == io::ErrorKind::InvalidData =>
            {
                break HeadOutcome::Closed
            }
            Err(LineError::Io(e)) => return Err(e),
        }
        let header = line.trim_end();
        if header.is_empty() {
            break HeadOutcome::Request { method, target, close };
        }
        if let Some((name, value)) = header.split_once(':') {
            if name.eq_ignore_ascii_case("connection") && value.trim().eq_ignore_ascii_case("close")
            {
                close = true;
            }
        }
    };
    // Restore the idle allowance for the next request.
    ctl.set_read_timeout(Some(budget))?;
    Ok(outcome)
}

fn http_request(method: &str, target: &str) -> Result<Request, HttpError> {
    if method != "GET" {
        return Err(HttpError::Bad(format!("unsupported method {method:?}; all endpoints are GET")));
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };
    let req = match path {
        "/snapshot" => Request::Snapshot,
        "/aggregate" => Request::Aggregate,
        "/subscribe" => Request::Subscribe,
        "/top_k_worst" => Request::TopK(parse_param(query, "k")?),
        "/count_below" => Request::CountBelow(parse_param(query, "t")?),
        "/auc_histogram" => Request::AucHistogram(parse_param(query, "bins")?),
        "/score_histogram" => Request::ScoreHistogram(parse_param(query, "bins")?),
        other => return Err(HttpError::NotFound(other.to_string())),
    };
    validate(&req).map_err(HttpError::Bad)?;
    Ok(req)
}

fn parse_param<T: std::str::FromStr>(query: &str, name: &str) -> Result<T, HttpError>
where
    T::Err: std::fmt::Display,
{
    let raw = query
        .split('&')
        .find_map(|kv| kv.strip_prefix(name).and_then(|rest| rest.strip_prefix('=')))
        .ok_or_else(|| HttpError::Bad(format!("missing query parameter {name}")))?;
    raw.parse()
        .map_err(|e| HttpError::Bad(format!("query parameter {name}={raw}: {e}")))
}

fn error_body(msg: &str) -> String {
    use std::fmt::Write as _;
    let mut out = String::with_capacity(msg.len() + 16);
    out.push_str("{\"error\":\"");
    for ch in msg.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push_str("\"}");
    out
}

fn write_http(
    stream: &mut TcpStream,
    status: u16,
    body: &str,
    close: bool,
    seq: u64,
) -> io::Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        408 => "Request Timeout",
        431 => "Request Header Fields Too Large",
        503 => "Service Unavailable",
        _ => "Error",
    };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nX-Fleet-Seq: {seq}\r\nConnection: {}\r\n\r\n",
        body.len(),
        if close { "close" } else { "keep-alive" }
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())
}
