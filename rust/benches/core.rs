//! Core estimator micro-bench: per-update and per-read cost of the
//! single-window estimators, and the cached-vs-full-scan read
//! comparison behind the incremental-`a2` tentpole.
//!
//! `cargo bench --bench core [-- --updates N] [-- --budget-ms B]`
//!
//! For every window size `k ∈ {1e3, 1e5}`:
//!
//! * `naive` — raw multiset, `O(k)` remove, `O(k log k)` sort per read
//!   (the from-scratch baseline of §5);
//! * `exact` — augmented tree, `O(log k)` update, `O(k)` read
//!   (Brzezinski & Stefanowski);
//! * `exact_maintained` — the delta-maintained exact estimator:
//!   `O(log k)` update, `O(1)` read off its running doubled-area
//!   accumulator, no approximation. Timed with both read paths like
//!   `approx` (its scan is the full Eq. 1 tree walk), so the JSON rows
//!   carry the naive / exact-maintained / approx three-way comparison;
//! * `binned` — the bounded-score count-array fast path at the
//!   resolution the fleet's auto-selection rule picks for ε = 0.01
//!   (`bins = ⌈2/ε⌉ = 200` over this trace's declared `[0, 1]`):
//!   `O(bins)` update independent of `k`, `O(1)` cached read, a fixed
//!   `2·bins` cells of footprint. The acceptance target is its update
//!   beating `approx` at ε = 0.01, k = 1e5;
//! * `approx(ε)` for `ε ∈ {0.5, 0.1, 0.01}` — the paper's estimator,
//!   `O((log k)/ε)` update, measured with **both** read paths:
//!   - `cached_read_ns` — [`Window::auc`]: the `O(1)` read off the
//!     running doubled-area accumulator (`DESIGN.md`
//!     §Incremental-reads);
//!   - `full_scan_read_ns` — `ApproxAuc::auc_full_scan`: the retained
//!     Algorithm 4 scan over `C`, i.e. what every read cost before the
//!     accumulator existed. `read_speedup` is their ratio.
//!
//! Windows are filled to capacity before timing; updates are then
//! steady-state churn (every push evicts). Reads and updates are
//! budget-capped (`--budget-ms`, default 150) so the expensive
//! baselines cannot stall CI; absolute numbers from CI runners are
//! noise — the *shape* (cached read flat in `1/ε` and `k`, scan read
//! growing with `|C|`) is the point.
//!
//! Besides the human-readable table, the run writes machine-readable
//! `BENCH_core.json` at the repository root (asserted present, with
//! the cached-vs-scan rows, by the CI bench-smoke job).

use std::fmt::Write as _;
use std::hint::black_box;
use std::time::Instant;

use streamauc::coordinator::window::Window;
use streamauc::coordinator::{ApproxAuc, BinnedAuc, ExactAuc, MaintainedExactAuc, NaiveAuc};
use streamauc::stream::Pcg;

const WINDOWS: [usize; 2] = [1_000, 100_000];
const EPSILONS: [f64; 3] = [0.5, 0.1, 0.01];

struct Row {
    estimator: &'static str,
    k: usize,
    /// `None` for the exact estimators (no accuracy knob).
    epsilon: Option<f64>,
    update_ns: f64,
    /// The estimator's default read path.
    read_ns: f64,
    /// Approx only: the retained full-scan read and its slowdown.
    full_scan_read_ns: Option<f64>,
    /// Approx only: `|C|` at measurement time (what the scan walks).
    compressed_len: Option<usize>,
}

/// ns/op of `op`, executed in blocks of `block` between clock checks
/// (so sub-10ns ops are not swamped by `Instant::now`), capped by both
/// the time budget and `max_iters`.
fn ns_per(budget_ms: u64, max_iters: u64, block: u64, mut op: impl FnMut()) -> f64 {
    let start = Instant::now();
    let mut iters = 0u64;
    while iters < max_iters {
        for _ in 0..block {
            op();
        }
        iters += block;
        if start.elapsed().as_millis() >= u128::from(budget_ms) {
            break;
        }
    }
    start.elapsed().as_nanos() as f64 / iters.max(1) as f64
}

/// Pre-generated churn trace: scores/labels cycled through the window.
fn trace(len: usize, seed: u64) -> Vec<(f64, bool)> {
    let mut rng = Pcg::seed(seed);
    (0..len).map(|_| (rng.uniform(), rng.chance(0.5))).collect()
}

/// Fill a window to capacity, then time steady-state updates and the
/// default read. Returns (update_ns, read_ns).
fn measure<E: streamauc::coordinator::AucEstimator>(
    mut win: Window<E>,
    events: &[(f64, bool)],
    budget_ms: u64,
    max_updates: u64,
    update_block: u64,
    read_block: u64,
) -> (Window<E>, f64, f64) {
    let k = win.capacity();
    for &(s, l) in &events[..k] {
        win.push(s, l);
    }
    let mut cursor = k;
    let update_ns = ns_per(budget_ms, max_updates, update_block, || {
        let (s, l) = events[cursor % events.len()];
        cursor += 1;
        win.push(s, l);
    });
    let mut acc = 0.0;
    let read_ns = ns_per(budget_ms, max_updates.max(1 << 20), read_block, || {
        acc += win.auc();
    });
    black_box(acc);
    (win, update_ns, read_ns)
}

fn flag(args: &[String], name: &str, default: u64) -> u64 {
    match args.iter().position(|a| a == name) {
        Some(i) => args
            .get(i + 1)
            .unwrap_or_else(|| panic!("{name} N"))
            .parse()
            .unwrap_or_else(|_| panic!("{name} N")),
        None => default,
    }
}

fn json_report(updates: u64, budget_ms: u64, rows: &[Row]) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    let _ = writeln!(s, "  \"bench\": \"core\",");
    let _ = writeln!(s, "  \"unit\": \"ns_per_op\",");
    let _ = writeln!(s, "  \"max_updates\": {updates},");
    let _ = writeln!(s, "  \"budget_ms\": {budget_ms},");
    s.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let eps = match r.epsilon {
            Some(e) => format!("{e}"),
            None => "null".to_string(),
        };
        let _ = write!(
            s,
            "    {{\"estimator\": \"{}\", \"k\": {}, \"epsilon\": {eps}, \
             \"update_ns\": {:.1}, ",
            r.estimator, r.k, r.update_ns
        );
        match (r.full_scan_read_ns, r.compressed_len) {
            (Some(scan), Some(clen)) => {
                let _ = write!(
                    s,
                    "\"cached_read_ns\": {:.1}, \"full_scan_read_ns\": {scan:.1}, \
                     \"read_speedup\": {:.3}, \"compressed_len\": {clen}}}",
                    r.read_ns,
                    scan / r.read_ns,
                );
            }
            _ => {
                let _ = write!(s, "\"read_ns\": {:.1}}}", r.read_ns);
            }
        }
        s.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    s.push_str("  ]\n}\n");
    s
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let updates = flag(&args, "--updates", 40_000);
    let budget_ms = flag(&args, "--budget-ms", 150);

    println!("== core: per-update / per-read ns (naive | exact | binned | approx) ==");
    println!("   (budget {budget_ms} ms/op-class, ≤ {updates} timed updates/row)\n");
    println!(
        "{:>8}  {:>11}  {:>5}  {:>11}  {:>12}  {:>12}  {:>8}  {:>5}",
        "k", "estimator", "ε", "update", "read", "scan read", "speedup", "|C|"
    );

    let mut rows = Vec::new();
    for &k in &WINDOWS {
        // Enough events to fill + churn without recycling too tightly.
        let events = trace(k + 65_536, 0xC0DE ^ k as u64);

        // Naive: O(k) removal per churn update, O(k log k) per read —
        // small blocks, the budget does the capping.
        let (_, update_ns, read_ns) = measure(
            Window::with_estimator(k, NaiveAuc::new()),
            &events,
            budget_ms,
            updates,
            (50_000 / k as u64).max(1),
            1,
        );
        println!("{k:>8}  {:>11}  {:>5}  {update_ns:>9.0}ns  {read_ns:>10.0}ns", "naive", "-");
        rows.push(Row {
            estimator: "naive",
            k,
            epsilon: None,
            update_ns,
            read_ns,
            full_scan_read_ns: None,
            compressed_len: None,
        });

        let (_, update_ns, read_ns) = measure(
            Window::with_estimator(k, ExactAuc::new()),
            &events,
            budget_ms,
            updates,
            256,
            (200_000 / k as u64).max(1),
        );
        println!("{k:>8}  {:>11}  {:>5}  {update_ns:>9.0}ns  {read_ns:>10.0}ns", "exact", "-");
        rows.push(Row {
            estimator: "exact",
            k,
            epsilon: None,
            update_ns,
            read_ns,
            full_scan_read_ns: None,
            compressed_len: None,
        });

        // Delta-maintained exact: same tree as `exact`, but the read
        // comes off the running accumulator — O(1) and bit-identical
        // to the Eq. 1 scan. `compressed_len` reports its footprint
        // (distinct-score tree nodes ≈ k in this continuum trace).
        let (win, update_ns, cached_read_ns) = measure(
            Window::with_estimator(k, MaintainedExactAuc::new()),
            &events,
            budget_ms,
            updates,
            256,
            4_096,
        );
        let mut acc = 0.0;
        let scan_ns = ns_per(budget_ms, updates.max(1 << 20), 64, || {
            acc += win.estimator().auc_full_scan();
        });
        black_box(acc);
        assert_eq!(
            win.auc().to_bits(),
            win.estimator().auc_full_scan().to_bits(),
            "maintained cached and scan reads diverged (k = {k})"
        );
        let nodes = win.estimator().distinct_scores();
        println!(
            "{k:>8}  {:>11}  {:>5}  {update_ns:>9.0}ns  {cached_read_ns:>10.0}ns  \
             {scan_ns:>10.0}ns  {:>7.1}x  {nodes:>5}",
            "exact-maint",
            "-",
            scan_ns / cached_read_ns,
        );
        rows.push(Row {
            estimator: "exact_maintained",
            k,
            epsilon: None,
            update_ns,
            read_ns: cached_read_ns,
            full_scan_read_ns: Some(scan_ns),
            compressed_len: Some(nodes),
        });

        // Binned bounded-score fast path at the ε = 0.01 auto
        // resolution (`bins = ⌈2/0.01⌉ = 200` over the trace's [0, 1]):
        // the update is an O(bins) prefix sum over contiguous counts,
        // independent of k; the read comes off the running accumulator.
        // `compressed_len` reports the fixed 2·bins-cell footprint.
        let bins = 200;
        let (win, update_ns, cached_read_ns) = measure(
            Window::with_estimator(k, BinnedAuc::new(bins, 0.0, 1.0)),
            &events,
            budget_ms,
            updates,
            256,
            4_096,
        );
        let mut acc = 0.0;
        let scan_ns = ns_per(budget_ms, updates.max(1 << 20), 64, || {
            acc += win.estimator().auc_full_scan();
        });
        black_box(acc);
        assert_eq!(
            win.auc().to_bits(),
            win.estimator().auc_full_scan().to_bits(),
            "binned cached and scan reads diverged (k = {k})"
        );
        println!(
            "{k:>8}  {:>11}  {:>5}  {update_ns:>9.0}ns  {cached_read_ns:>10.0}ns  \
             {scan_ns:>10.0}ns  {:>7.1}x  {:>5}",
            "binned",
            "-",
            scan_ns / cached_read_ns,
            2 * bins,
        );
        rows.push(Row {
            estimator: "binned",
            k,
            epsilon: None,
            update_ns,
            read_ns: cached_read_ns,
            full_scan_read_ns: Some(scan_ns),
            compressed_len: Some(2 * bins),
        });

        for &eps in &EPSILONS {
            let (win, update_ns, cached_read_ns) = measure(
                Window::with_estimator(k, ApproxAuc::new(eps)),
                &events,
                budget_ms,
                updates,
                256,
                4_096,
            );
            // The retained Algorithm 4 scan on the identical window —
            // what the cached read replaced.
            let mut acc = 0.0;
            let scan_ns = ns_per(budget_ms, updates.max(1 << 20), 512, || {
                acc += win.estimator().auc_full_scan();
            });
            black_box(acc);
            assert_eq!(
                win.auc().to_bits(),
                win.estimator().auc_full_scan().to_bits(),
                "cached and scan reads diverged (k = {k}, ε = {eps})"
            );
            let clen = win.estimator().compressed_len();
            println!(
                "{k:>8}  {:>11}  {eps:>5}  {update_ns:>9.0}ns  {cached_read_ns:>10.0}ns  \
                 {scan_ns:>10.0}ns  {:>7.1}x  {clen:>5}",
                "approx",
                scan_ns / cached_read_ns,
            );
            rows.push(Row {
                estimator: "approx",
                k,
                epsilon: Some(eps),
                update_ns,
                read_ns: cached_read_ns,
                full_scan_read_ns: Some(scan_ns),
                compressed_len: Some(clen),
            });
        }
    }
    println!("\n(speedup = scan read / cached read; both are bit-identical by assert)");

    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_core.json");
    let report = json_report(updates, budget_ms, &rows);
    match std::fs::write(&path, &report) {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
