//! Compact frozen form of an idle stream — cold-stream hibernation.
//!
//! A hibernated stream trades its live structures (support tree `T`,
//! positive index `TP`, lists `P`/`C`, window FIFO — several arena
//! slots per window entry) for three contiguous buffers: the window's
//! scores in arrival order, the labels as a bitset, and — for the
//! `(1+ε)`-compressed estimator only — the finite keys of the
//! compressed list `C`. That is ~9 bytes per entry instead of the
//! live form's ~60–100, and every arena slot the stream held returns
//! to the shard's free lists at freeze time ([`super::shard::Shard`]
//! then resets the arenas outright once no live-form stream remains).
//!
//! **Why rehydration is bit-identical.** Every estimator in this crate
//! keeps state that is a pure function of the window *content* —
//! counts, totals and a doubled-area accumulator that is proven
//! bit-equal to the content-determined full scan at every op boundary
//! (`coordinator/*::check_invariants`) — with one exception: the shape
//! of the compressed list `C` depends on the *history* of inserts and
//! compressions, not just the current content. So the frozen form
//! stores `C`'s keys explicitly. Thawing replays the entries into the
//! support structure (a multiset — arrival order only perturbs
//! internal node placement, never a counter), rebuilds `C` from the
//! stored keys (the gap counters `gp`/`gn` are pure functions of the
//! key set and the window content), and re-derives the accumulator
//! from the content-determined scan — which the live accumulator was
//! bit-equal to when the stream froze. Hence the thawed estimator
//! reads the exact same `auc()` bits, passes `check_invariants`, and
//! every subsequent operation proceeds from bit-identical state: a
//! stream that hibernated is indistinguishable, digest-for-digest,
//! from one that never did (`tests/differential.rs`,
//! `tests/executor.rs`). [`super::shard::Shard`] additionally asserts
//! the estimate bits on every thaw.
//!
//! **Tiering.** Hibernation sits between staying hot and eviction
//! ([`super::AucFleet::evict_idle`]): an evicted stream loses its
//! window, counters and monitor baseline and starts cold on
//! reappearance; a hibernated one keeps everything — it still answers
//! snapshots and queries (estimate pinned by the frozen form, sketch
//! contribution retained) and resumes exactly where it left off. See
//! `rust/DESIGN.md` §Memory.

use std::collections::VecDeque;

use crate::collections::Score;
use crate::coordinator::approx::ApproxCore;
use crate::coordinator::canon;
use crate::coordinator::support::EstimatorArenas;

use super::config::{EstimatorKind, PooledEstimator, StreamConfig};
use super::shard::PooledWindow;

/// One hibernated stream: configuration plus the window serialized
/// into contiguous buffers. Holds no arena slots.
#[derive(Clone, Debug)]
pub(super) struct FrozenStream {
    /// The stream's configuration — everything needed to rebuild the
    /// estimator on thaw.
    cfg: StreamConfig,
    /// The estimate at freeze time; bit-equal to what the rehydrated
    /// estimator reads (asserted on every thaw).
    auc: f64,
    /// Estimator structure size (cells/nodes) at freeze time — what
    /// snapshots report as `compressed_len` while frozen.
    footprint_cells: usize,
    /// Window scores, oldest first.
    scores: Box<[f64]>,
    /// Window labels as a bitset, same order (bit i ↔ `scores[i]`).
    labels: Box<[u64]>,
    /// Finite keys of the compressed list `C`, ascending — present
    /// only for the `(1+ε)`-compressed estimator (empty otherwise).
    c_keys: Box<[f64]>,
}

impl FrozenStream {
    /// Serialize a live window into the frozen form. Reads only; the
    /// caller frees the live structures afterwards
    /// ([`PooledEstimator::free_in`]).
    pub(super) fn freeze(
        win: &PooledWindow,
        cfg: &StreamConfig,
        ars: &EstimatorArenas,
    ) -> FrozenStream {
        let n = win.len();
        let mut scores = Vec::with_capacity(n);
        #[allow(clippy::manual_div_ceil)] // usize::div_ceil is 1.73; crate floor is 1.66
        let mut labels = vec![0u64; (n + 63) / 64];
        for (i, (s, p)) in win.entries().enumerate() {
            scores.push(s);
            if p {
                labels[i / 64] |= 1 << (i % 64);
            }
        }
        let c_keys: Box<[f64]> = match &win.est {
            PooledEstimator::Approx(e) => e.compressed_keys(ars).into(),
            PooledEstimator::Exact(_) | PooledEstimator::Binned(_) => Box::default(),
        };
        FrozenStream {
            cfg: *cfg,
            auc: win.auc(),
            footprint_cells: win.est.footprint(),
            scores: scores.into(),
            labels: labels.into(),
            c_keys,
        }
    }

    /// Rebuild the live window from the frozen buffers (see the module
    /// docs for why the result is bit-identical to the frozen state).
    pub(super) fn thaw(&self, ars: &mut EstimatorArenas) -> PooledWindow {
        let est = match self.cfg.estimator {
            EstimatorKind::Approx { epsilon } => {
                // Replay content into the support structure only, then
                // reconstruct `C` from its stored keys — replaying
                // through the full insert path would re-run compression
                // and grow a history-dependent, generally different `C`.
                let mut core = ApproxCore::new_in(ars, epsilon);
                for (s, p) in self.entries() {
                    let sc = Score(canon(s));
                    if p {
                        core.sup.add_pos(ars, sc);
                    } else {
                        core.sup.add_neg(ars, sc);
                    }
                }
                core.rebuild_in(ars, &self.c_keys);
                PooledEstimator::Approx(core)
            }
            EstimatorKind::ExactMaintained | EstimatorKind::Binned { .. } => {
                // Maintained-exact and binned state is entirely
                // content-determined: plain replay reproduces it.
                let mut est = self.cfg.estimator.build_in(ars);
                for (s, p) in self.entries() {
                    est.insert_in(ars, s, p);
                }
                est
            }
        };
        let fifo: VecDeque<(f64, bool)> = self.entries().collect();
        PooledWindow::from_parts(est, fifo, self.cfg.window)
    }

    /// The pinned estimate (bit-equal to the rehydrated read).
    pub(super) fn auc(&self) -> f64 {
        self.auc
    }

    /// Window entries held.
    pub(super) fn len(&self) -> usize {
        self.scores.len()
    }

    /// Estimator structure size (cells/nodes) at freeze time.
    pub(super) fn footprint_cells(&self) -> usize {
        self.footprint_cells
    }

    /// Logical bytes of the frozen buffers.
    pub(super) fn footprint_bytes(&self) -> usize {
        (self.scores.len() + self.labels.len() + self.c_keys.len()) * 8
    }

    /// Window contents, oldest first — identical to what the live
    /// window's `entries()` returned at freeze time.
    pub(super) fn entries(&self) -> impl Iterator<Item = (f64, bool)> + '_ {
        self.scores
            .iter()
            .enumerate()
            .map(|(i, &s)| (s, self.labels[i / 64] >> (i % 64) & 1 == 1))
    }
}

// Frozen streams live inside shards, which cross worker threads.
const _: () = {
    const fn assert_send<T: Send>() {}
    assert_send::<FrozenStream>();
};
