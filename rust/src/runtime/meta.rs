//! Minimal JSON parsing for the artifact shape contract.
//!
//! `artifacts/meta.json` is written by `python/compile/aot.py` and read
//! here. serde is unavailable in this offline environment (DESIGN.md
//! §Substitutions), so a small recursive-descent parser covers the JSON
//! subset we emit: objects, arrays, strings, numbers, booleans, null.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number (kept as f64; the contract only uses small ints).
    Num(f64),
    /// String (escapes `\" \\ \/ \n \t \r \u`).
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (sorted keys).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Field as u64 (error if absent or not numeric).
    pub fn req_u64(&self, key: &str) -> Result<u64> {
        match self.get(key) {
            Some(Json::Num(n)) => Ok(*n as u64),
            other => Err(anyhow!("field {key:?}: expected number, got {other:?}")),
        }
    }

    /// Field as string slice.
    pub fn req_str(&self, key: &str) -> Result<&str> {
        match self.get(key) {
            Some(Json::Str(s)) => Ok(s),
            other => Err(anyhow!("field {key:?}: expected string, got {other:?}")),
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected {:?} at byte {}", b as char, self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {other:?} at byte {}", self.pos),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse().with_context(|| format!("bad number {s:?}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow!("short \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad \\u{code:04x}"))?,
                            );
                            self.pos += 4;
                        }
                        other => bail!("bad escape {other:?}"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => bail!("expected , or ] got {other:?} at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => bail!("expected , or }} got {other:?} at byte {}", self.pos),
            }
        }
    }
}

/// The artifact shape contract (parsed `meta.json`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Meta {
    /// Feature width the model was lowered with (rust zero-pads to it).
    pub dims: usize,
    /// Scoring batch size.
    pub score_batch: usize,
    /// Training batch size.
    pub train_batch: usize,
}

impl Meta {
    /// Read and validate `meta.json` from the artifact directory.
    pub fn load(artifact_dir: &Path) -> Result<Meta> {
        let path = artifact_dir.join("meta.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts`)", path.display()))?;
        let json = Json::parse(&text).context("parse meta.json")?;
        let dims = json.req_u64("dims")? as usize;
        let score = json
            .get("score_batch")
            .ok_or_else(|| anyhow!("meta.json: missing score_batch"))?;
        let train = json
            .get("train_step")
            .ok_or_else(|| anyhow!("meta.json: missing train_step"))?;
        let meta = Meta {
            dims,
            score_batch: score.req_u64("batch")? as usize,
            train_batch: train.req_u64("batch")? as usize,
        };
        if meta.dims == 0 || meta.score_batch == 0 || meta.train_batch == 0 {
            bail!("meta.json: zero shape entry: {meta:?}");
        }
        Ok(meta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, {"b": "x"}, null], "c": false}"#).unwrap();
        let a = v.get("a").unwrap();
        match a {
            Json::Arr(items) => {
                assert_eq!(items[0], Json::Num(1.0));
                assert_eq!(items[1].get("b"), Some(&Json::Str("x".into())));
                assert_eq!(items[2], Json::Null);
            }
            _ => panic!("not an array"),
        }
        assert_eq!(v.get("c"), Some(&Json::Bool(false)));
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "tru", "{\"a\" 1}", "1 2", "\"unterminated"] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn meta_roundtrip_with_real_writer_format() {
        let dir = std::env::temp_dir().join("streamauc-meta-test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("meta.json"),
            r#"{
  "dims": 128,
  "score_batch": {"batch": 1024, "inputs": ["w", "b", "x"], "outputs": ["scores"]},
  "train_step": {"batch": 256, "inputs": ["w", "b", "x", "y", "lr"], "outputs": ["w", "b", "loss"]},
  "score_convention": "larger score => more likely negative (paper §2)",
  "dtype": "f32"
}"#,
        )
        .unwrap();
        let meta = Meta::load(&dir).unwrap();
        assert_eq!(meta, Meta { dims: 128, score_batch: 1024, train_batch: 256 });
    }

    #[test]
    fn meta_missing_file_mentions_make() {
        let err = Meta::load(Path::new("/nonexistent-dir")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
