"""Layer 2: the JAX logistic-regression model (build-time only).

Defines the classifier the paper's pipeline uses to score streams —
forward scoring and a fused SGD training step — on top of the Pallas
kernels in :mod:`compile.kernels.logreg`. Both entry points are lowered
once to HLO text by :mod:`compile.aot` and executed from the rust
coordinator via PJRT; Python never runs on the streaming path.

Fixed shapes (HLO is shape-specialised; the rust side zero-pads):
  * feature width  ``DIMS = 128`` — covers hepmass (28), miniboone (50)
    and tvads (124) with zero padding;
  * scoring batch  ``SCORE_BATCH = 1024``;
  * training batch ``TRAIN_BATCH = 256``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import logreg

# Shape contract shared with the rust runtime via artifacts/meta.json.
DIMS = 128
SCORE_BATCH = 1024
TRAIN_BATCH = 256


def init_params(dims: int = DIMS):
    """Zero-initialised parameters (w, b)."""
    return jnp.zeros((dims,), jnp.float32), jnp.zeros((), jnp.float32)


def score_batch(w, b, x):
    """Scores for a feature batch via the fused Pallas kernel.

    Output follows the paper's convention (§2): *larger score ⇒ more
    likely negative*. ``sigmoid(x @ w + b)`` estimates P(label = 0 | x)
    when training uses ``1 − y`` as the regression target, which
    :func:`train_step` does.
    """
    return logreg.score_batch(w, b, x)


def loss(w, b, x, y01):
    """Mean logistic loss against the *negative-class* target
    ``1 − y``. Matches :func:`score_batch`'s convention."""
    t = 1.0 - y01
    logits = x @ w + b
    return jnp.mean(jnp.logaddexp(0.0, logits) - t * logits)


def train_step(w, b, x, y01, lr):
    """One fused SGD step; returns ``(w', b', loss)``.

    The gradient comes from the Pallas :func:`~compile.kernels.logreg.
    grad_partials` kernel (per-tile partials summed here, so the
    reduction lowers into the same HLO module). ``y01`` is the true
    label (1 = positive); the regression target is ``1 − y`` per the
    score convention above.
    """
    t = (1.0 - y01).astype(x.dtype)
    gw_parts, gb_parts = logreg.grad_partials(w, b, x, t)
    batch = x.shape[0]
    gw = jnp.sum(gw_parts, axis=0) / batch
    gb = jnp.sum(gb_parts) / batch
    new_w = w - lr * gw
    new_b = b - lr * gb
    return new_w, new_b, loss(w, b, x, y01)


def lowering_specs():
    """ShapeDtypeStructs for the two AOT entry points, in argument
    order. Shared by :mod:`compile.aot` and the tests."""
    f32 = jnp.float32
    score = (
        jax.ShapeDtypeStruct((DIMS,), f32),          # w
        jax.ShapeDtypeStruct((), f32),               # b
        jax.ShapeDtypeStruct((SCORE_BATCH, DIMS), f32),  # x
    )
    train = (
        jax.ShapeDtypeStruct((DIMS,), f32),          # w
        jax.ShapeDtypeStruct((), f32),               # b
        jax.ShapeDtypeStruct((TRAIN_BATCH, DIMS), f32),  # x
        jax.ShapeDtypeStruct((TRAIN_BATCH,), f32),   # y (0/1 floats)
        jax.ShapeDtypeStruct((), f32),               # lr
    )
    return score, train
