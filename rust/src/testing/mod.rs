//! In-repo property-testing harness.
//!
//! `proptest` is not available in this offline environment (see DESIGN.md
//! §Substitutions), so invariants are exercised with a deterministic
//! randomized harness: each property runs over many seeded cases, and a
//! failure reports the case seed for exact replay. Shrinking is
//! approximated by replaying with geometrically shorter operation
//! prefixes (the op-sequence generators all take an explicit length).

pub use crate::stream::rng::Pcg;

/// Run `prop` for `cases` deterministic cases derived from `master_seed`.
///
/// On panic, re-raises with the failing case seed in the message so the
/// case can be replayed in isolation:
/// `check(0xBEEF, 1, |rng| ...)` with the printed seed.
pub fn check(master_seed: u64, cases: u64, mut prop: impl FnMut(&mut Pcg)) {
    for case in 0..cases {
        let seed = master_seed ^ case.wrapping_mul(0x9e3779b97f4a7c15);
        let mut rng = Pcg::seed(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .map(String::as_str)
                .or_else(|| err.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property failed (case {case}, replay seed {seed:#x}): {msg}");
        }
    }
}

/// A random operation on a sliding-window estimator, drawn by the
/// generators below and consumed by the coordinator property tests.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Op {
    /// Insert a (score, label) pair into the window.
    Insert { score: f64, pos: bool },
    /// Remove a previously inserted pair (generators only emit removals of
    /// live pairs).
    Remove { score: f64, pos: bool },
}

/// Generate a window-like op sequence: bounded live multiset, scores drawn
/// from a small grid (forcing duplicate-score nodes, the regime where the
/// paper's pseudo-code is subtlest) or a continuum, removals in FIFO or
/// random order.
pub fn gen_ops(rng: &mut Pcg, len: usize, max_live: usize, score_grid: Option<u64>) -> Vec<Op> {
    let mut ops = Vec::with_capacity(len);
    let mut live: Vec<(f64, bool)> = Vec::new();
    let fifo = rng.chance(0.5);
    for _ in 0..len {
        let must_remove = live.len() >= max_live;
        let must_insert = live.is_empty();
        let insert = must_insert || (!must_remove && rng.chance(0.55));
        if insert {
            let score = match score_grid {
                Some(g) => rng.below(g) as f64 / g as f64,
                None => rng.uniform(),
            };
            let pos = rng.chance(0.5);
            live.push((score, pos));
            ops.push(Op::Insert { score, pos });
        } else {
            let idx = if fifo { 0 } else { rng.below(live.len() as u64) as usize };
            let (score, pos) = live.remove(idx);
            ops.push(Op::Remove { score, pos });
        }
    }
    ops
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut n = 0;
        check(42, 10, |_| n += 1);
        assert_eq!(n, 10);
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn check_reports_seed_on_failure() {
        check(42, 10, |rng| {
            assert!(rng.below(10) < 9, "drew a 9");
        });
    }

    #[test]
    fn gen_ops_removals_are_live() {
        let mut rng = Pcg::seed(1);
        for _ in 0..50 {
            let ops = gen_ops(&mut rng, 200, 20, Some(8));
            let mut live: Vec<(f64, bool)> = Vec::new();
            for op in ops {
                match op {
                    Op::Insert { score, pos } => live.push((score, pos)),
                    Op::Remove { score, pos } => {
                        let i = live
                            .iter()
                            .position(|&(s, p)| s == score && p == pos)
                            .expect("removal of dead pair");
                        live.remove(i);
                    }
                }
            }
            assert!(live.len() <= 20);
        }
    }

    #[test]
    fn gen_ops_respects_max_live() {
        let mut rng = Pcg::seed(2);
        let ops = gen_ops(&mut rng, 500, 10, None);
        let mut live = 0i64;
        for op in ops {
            match op {
                Op::Insert { .. } => live += 1,
                Op::Remove { .. } => live -= 1,
            }
            assert!(live <= 10 && live >= 0);
        }
    }
}
