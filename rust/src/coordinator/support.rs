//! Supporting data structures for estimating AUC (paper §3).
//!
//! [`SupportCore`] bundles the three §3 structures and their maintenance:
//!
//! * `T` — augmented red-black tree over distinct scores with per-node
//!   counters `p(v)`, `n(v)` and subtree sums `accpos(v)`, `accneg(v)`;
//! * `TP` — red-black tree over *positive* nodes, answering `MaxPos(s)`
//!   (largest positive node with score `≤ s`) in `O(log k)`;
//! * `P` — weighted linked list of all positive nodes with gap counters,
//!   giving `AddNext` its `O(1)` access to `gp(v; P)`, `gn(v; P)`.
//!
//! Both `T` and the lists carry the `±∞` sentinel nodes of §3.1, so every
//! query has a well-defined predecessor.
//!
//! Like the collections underneath, the structure comes in two forms:
//! the storage-free [`SupportCore`] whose nodes and cells live in an
//! [`EstimatorArenas`] passed into every call (the fleet keeps one
//! arena bundle per shard, shared by every stream in it), and the
//! self-contained [`SupportTree`] wrapper bundling a core with private
//! arenas for standalone use (`rust/DESIGN.md` §Memory).
//!
//! Two places fix small gaps in the paper's pseudo-code (behaviour is
//! unchanged for unique scores, which is the paper's implicit setting):
//!
//! 1. Algorithm 3 line 8 passes `1` for the positive-gap split; with
//!    duplicate scores the positives in `[s(w), s(v))` amount to `p(w)`,
//!    which is what [`SupportCore::add_pos`] uses (computed from
//!    `HeadStats` and asserted equal to `p(w)` in debug builds).
//! 2. Algorithm 3 only shows the new-node path; when the score already
//!    exists as a positive node, `gp(v; P)` must still be increased.

use crate::collections::arena::Arena;
use crate::collections::rbtree::{Node, RbTreeCore};
use crate::collections::weighted_list::{CellArena, Cells, ListCore};
use crate::collections::{Augment, CellId, NodeId, Score};

/// Per-node label counters (paper §3.1): `p(v)` positives and `n(v)`
/// negatives sharing the node's score.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Counts {
    /// `p(v)` — window entries with this score and label 1.
    pub p: u64,
    /// `n(v)` — window entries with this score and label 0.
    pub n: u64,
}

/// Subtree sums `accpos(v)` / `accneg(v)` (paper §3.1).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Acc {
    /// Sum of `p(w)` over the node's subtree (node included).
    pub pos: u64,
    /// Sum of `n(w)` over the node's subtree.
    pub neg: u64,
}

impl Augment<Counts> for Acc {
    #[inline]
    fn recompute(val: &Counts, left: Option<&Self>, right: Option<&Self>) -> Self {
        Acc {
            pos: val.p + left.map_or(0, |a| a.pos) + right.map_or(0, |a| a.pos),
            neg: val.n + left.map_or(0, |a| a.neg) + right.map_or(0, |a| a.neg),
        }
    }
}

/// The four backing slabs every ε-sketch / exact estimator allocates
/// from: `T` nodes, `TP` nodes, `P` cells and `C` cells. One bundle is
/// shared by **many** streams (the fleet owns one per shard); a
/// standalone estimator owns a private bundle. Per-role arenas keep the
/// `node → cell` membership maps collision-free: a tree node belongs to
/// exactly one stream, and each list role gets its own map.
#[derive(Clone, Debug, Default)]
pub(crate) struct EstimatorArenas {
    /// `T` nodes (also used by the maintained-exact estimator, which is
    /// a `T`-only core).
    pub(crate) t: Arena<Node<Counts, Acc>>,
    /// `TP` nodes.
    pub(crate) tp: Arena<Node<NodeId, ()>>,
    /// `P` cells.
    pub(crate) p: CellArena,
    /// `C` cells.
    pub(crate) c: CellArena,
}

impl EstimatorArenas {
    /// Logical bytes of all live nodes and cells (content-determined —
    /// safe to surface in snapshots and wire digests; see
    /// [`Arena::live_bytes`]).
    pub(crate) fn live_bytes(&self) -> usize {
        self.t.live_bytes() + self.tp.live_bytes() + self.p.live_bytes() + self.c.live_bytes()
    }

    /// Drop all storage. Every core allocating from the bundle must
    /// have been freed first ([`Arena::reset`] asserts it) — the
    /// bulk-release hook for a shard whose last live stream froze.
    pub(crate) fn reset(&mut self) {
        self.t.reset();
        self.tp.reset();
        self.p.reset();
        self.c.reset();
    }

    /// Release retained capacity without disturbing live slots.
    pub(crate) fn shrink_to_fit(&mut self) {
        self.t.shrink_to_fit();
        self.tp.shrink_to_fit();
        self.p.shrink_to_fit();
        self.c.shrink_to_fit();
    }
}

/// Storage-free form of the bundled §3 structure: tree roots, list
/// heads and the class totals — a few dozen bytes per stream, with all
/// nodes and cells in a shared [`EstimatorArenas`]. The same-arena rule
/// applies: every call must receive the bundle the core was built in.
#[derive(Clone, Copy, Debug)]
pub(crate) struct SupportCore {
    /// `T`: all distinct scores in the window (+ sentinels).
    pub(crate) t: RbTreeCore,
    /// `TP`: scores of positive nodes (+ sentinels) → node in `T`.
    tp: RbTreeCore,
    /// `P`: weighted linked list over positive nodes (+ sentinels).
    pub(crate) p: ListCore,
    neg_sentinel: NodeId,
    pos_sentinel: NodeId,
    total_pos: u64,
    total_neg: u64,
}

impl SupportCore {
    /// Fresh structure holding only the two sentinels, allocated from
    /// `ars`.
    pub(crate) fn new_in(ars: &mut EstimatorArenas) -> Self {
        let mut t = RbTreeCore::new();
        let (lo, _) = t.insert(&mut ars.t, Score::NEG_SENTINEL, Counts::default);
        let (hi, _) = t.insert(&mut ars.t, Score::POS_SENTINEL, Counts::default);
        let mut tp = RbTreeCore::new();
        tp.insert(&mut ars.tp, Score::NEG_SENTINEL, || lo);
        tp.insert(&mut ars.tp, Score::POS_SENTINEL, || hi);
        let mut p = ListCore::new();
        p.push_back(&mut ars.p, lo, f64::NEG_INFINITY, 0, 0);
        p.push_back(&mut ars.p, hi, f64::INFINITY, 0, 0);
        SupportCore { t, tp, p, neg_sentinel: lo, pos_sentinel: hi, total_pos: 0, total_neg: 0 }
    }

    /// Release every node and cell back to the arenas (`O(k)`, no
    /// rebalancing). The core must not be used afterwards.
    pub(crate) fn free_in(&mut self, ars: &mut EstimatorArenas) {
        self.t.drain(&mut ars.t);
        self.tp.drain(&mut ars.tp);
        self.p.drain(&mut ars.p);
        self.total_pos = 0;
        self.total_neg = 0;
    }

    /// Total positive labels in the window.
    #[inline]
    pub(crate) fn total_pos(&self) -> u64 {
        self.total_pos
    }

    /// Total negative labels in the window.
    #[inline]
    pub(crate) fn total_neg(&self) -> u64 {
        self.total_neg
    }

    /// Window size `k` (all entries).
    #[inline]
    pub(crate) fn len(&self) -> usize {
        (self.total_pos + self.total_neg) as usize
    }

    /// True when the window holds no entries (sentinels don't count).
    #[inline]
    pub(crate) fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of distinct-score nodes in `T`, sentinels included.
    #[inline]
    pub(crate) fn t_len(&self) -> usize {
        self.t.len()
    }

    /// Logical bytes this structure's nodes occupy in the shared arenas:
    /// live node/cell counts times slot sizes. Deliberately *not* the
    /// arena capacity — capacity is allocation-history-dependent and
    /// would make per-stream footprints (and everything derived from
    /// them, e.g. served snapshots) depend on pool scheduling.
    pub(crate) fn live_bytes(&self) -> usize {
        use crate::collections::weighted_list::Cell;
        use std::mem::size_of;
        self.t.len() * size_of::<Node<Counts, Acc>>()
            + self.tp.len() * size_of::<Node<NodeId, ()>>()
            + self.p.len() * size_of::<Cell>()
    }

    /// The `−∞` sentinel node.
    #[inline]
    pub(crate) fn neg_sentinel(&self) -> NodeId {
        self.neg_sentinel
    }

    /// The `+∞` sentinel node.
    #[inline]
    pub(crate) fn pos_sentinel(&self) -> NodeId {
        self.pos_sentinel
    }

    /// Score of a `T` node.
    #[inline]
    pub(crate) fn score(&self, ars: &EstimatorArenas, v: NodeId) -> Score {
        self.t.key(&ars.t, v)
    }

    /// Label counters of a `T` node.
    #[inline]
    pub(crate) fn counts(&self, ars: &EstimatorArenas, v: NodeId) -> Counts {
        *self.t.val(&ars.t, v)
    }

    /// `MaxPos(s)` (paper §3.2): the positive node with the largest score
    /// `≤ s`, falling back to the `−∞` sentinel. Also returns its `P`
    /// cell. `O(log k)`.
    pub(crate) fn max_pos(&self, ars: &EstimatorArenas, s: Score) -> (NodeId, CellId) {
        let id = self.tp.floor(&ars.tp, s).expect("−∞ sentinel bounds every query");
        let node = *self.tp.val(&ars.tp, id);
        let cell = self.p.cell_of(&ars.p, node).expect("TP node must be in P");
        (node, cell)
    }

    /// `HeadStats(s)` (Algorithm 1): cumulative counts
    /// `hp = Σ_{s(v) < s} p(v)` and `hn = Σ_{s(v) < s} n(v)`, in
    /// `O(log k)`. Generalised to not require a node with score `s`.
    pub(crate) fn head_stats(&self, ars: &EstimatorArenas, s: Score) -> (u64, u64) {
        let mut hp = 0;
        let mut hn = 0;
        let mut cur = self.t.root();
        while let Some(v) = cur {
            if self.t.key(&ars.t, v) < s {
                let c = self.t.val(&ars.t, v);
                hp += c.p;
                hn += c.n;
                if let Some(l) = self.t.left(&ars.t, v) {
                    let a = self.t.aug(&ars.t, l);
                    hp += a.pos;
                    hn += a.neg;
                }
                cur = self.t.right(&ars.t, v);
            } else {
                cur = self.t.left(&ars.t, v);
            }
        }
        (hp, hn)
    }

    /// `AddTreePos(s)` (Algorithm 3): insert a positive entry. Returns the
    /// node holding the score. `O(log k)`.
    pub(crate) fn add_pos(&mut self, ars: &mut EstimatorArenas, s: Score) -> NodeId {
        debug_assert!(s.is_valid_entry(), "scores must be finite");
        // w = MaxPos(s) *before* the insertion.
        let (w, w_cell) = self.max_pos(ars, s);
        let (v, fresh_in_t) = self.t.insert(&mut ars.t, s, || Counts { p: 1, n: 0 });
        if !fresh_in_t {
            self.t.with_val_mut(&mut ars.t, v, |c| c.p += 1);
        }
        self.total_pos += 1;
        if w == v {
            // Score already existed as a positive node: its own gap in P
            // absorbs the new label (pseudo-code gap 2 in module docs).
            self.p.add_gp(&mut ars.p, w_cell, 1);
            self.p.add_cp(&mut ars.p, w_cell, 1);
        } else if self.p.contains(&ars.p, v) {
            // Unreachable: if v were positive before, MaxPos(s) == v.
            unreachable!("positive node not returned by MaxPos");
        } else {
            // v is new to P (either a brand-new node, or an existing
            // negative-only node turning positive). Account the new label
            // in w's gap, then split the gap at v.
            self.p.add_gp(&mut ars.p, w_cell, 1);
            let w_key = self.t.key(&ars.t, w);
            let (hp_w, hn_w) = self.head_stats(ars, w_key);
            let (hp_v, hn_v) = self.head_stats(ars, s);
            let p_wv = hp_v - hp_w;
            let n_wv = hn_v - hn_w;
            debug_assert_eq!(
                p_wv,
                self.t.val(&ars.t, w).p,
                "positives in [w, v) must equal p(w) since w = MaxPos"
            );
            let cv = *self.t.val(&ars.t, v);
            self.p.insert_after(&mut ars.p, w_cell, v, s.0, cv.p, cv.n, p_wv, n_wv);
            self.tp.insert(&mut ars.tp, s, || v);
        }
        v
    }

    /// `AddTreeNeg(s)` (§3.3): insert a negative entry. Returns the node.
    /// `O(log k)`.
    pub(crate) fn add_neg(&mut self, ars: &mut EstimatorArenas, s: Score) -> NodeId {
        debug_assert!(s.is_valid_entry(), "scores must be finite");
        let (v, fresh) = self.t.insert(&mut ars.t, s, || Counts { p: 0, n: 1 });
        if !fresh {
            self.t.with_val_mut(&mut ars.t, v, |c| c.n += 1);
        }
        self.total_neg += 1;
        let (_, u_cell) = self.max_pos(ars, s);
        self.p.add_gn(&mut ars.p, u_cell, 1);
        if self.p.key(&ars.p, u_cell) == s.0 {
            self.p.add_cn(&mut ars.p, u_cell, 1);
        }
        v
    }

    /// `RemoveTreePos(s)` (Algorithm 2): remove one positive entry with
    /// score `s` (must exist). `O(log k)`.
    pub(crate) fn remove_pos(&mut self, ars: &mut EstimatorArenas, s: Score) {
        let v = self.t.find(&ars.t, s).expect("remove_pos: score not present");
        let c = *self.t.val(&ars.t, v);
        assert!(c.p > 0, "remove_pos: node has no positive labels");
        self.t.with_val_mut(&mut ars.t, v, |c| c.p -= 1);
        self.total_pos -= 1;
        let v_cell = self.p.cell_of(&ars.p, v).expect("positive node must be in P");
        self.p.add_gp(&mut ars.p, v_cell, -1);
        self.p.add_cp(&mut ars.p, v_cell, -1);
        if c.p == 1 {
            // v is no longer positive: leaves P and TP; its remaining gap
            // (negatives between v and the next positive) folds into the
            // predecessor's gap.
            self.p.remove(&mut ars.p, v_cell);
            let tp_id = self.tp.find(&ars.tp, s).expect("positive node must be in TP");
            self.tp.remove(&mut ars.tp, tp_id);
            if c.n == 0 {
                self.t.remove(&mut ars.t, v);
            }
        }
    }

    /// `RemoveTreeNeg(s)` (§3.3): remove one negative entry with score `s`
    /// (must exist). `O(log k)`.
    pub(crate) fn remove_neg(&mut self, ars: &mut EstimatorArenas, s: Score) {
        let v = self.t.find(&ars.t, s).expect("remove_neg: score not present");
        let c = *self.t.val(&ars.t, v);
        assert!(c.n > 0, "remove_neg: node has no negative labels");
        self.t.with_val_mut(&mut ars.t, v, |c| c.n -= 1);
        self.total_neg -= 1;
        let (_, u_cell) = self.max_pos(ars, s);
        self.p.add_gn(&mut ars.p, u_cell, -1);
        if self.p.key(&ars.p, u_cell) == s.0 {
            self.p.add_cn(&mut ars.p, u_cell, -1);
        }
        if c.n == 1 && c.p == 0 {
            self.t.remove(&mut ars.t, v);
        }
    }

    /// Exact AUC by full in-order enumeration of `T` (Eq. 1); `O(k)`. This
    /// is the §5 baseline query (Brzezinski & Stefanowski recompute).
    pub(crate) fn exact_auc(&self, ars: &EstimatorArenas) -> f64 {
        let groups = self.t.iter_in(&ars.t).map(|id| {
            let c = self.t.val(&ars.t, id);
            (c.p, c.n)
        });
        let (a2, pos, neg) = super::auc_terms_doubled(groups);
        debug_assert_eq!(pos, self.total_pos);
        debug_assert_eq!(neg, self.total_neg);
        super::finish_auc(a2, pos, neg)
    }

    /// Iterate `(score, p, n)` for all live non-sentinel nodes ascending.
    pub(crate) fn groups<'a>(
        &'a self,
        ars: &'a EstimatorArenas,
    ) -> impl Iterator<Item = (Score, u64, u64)> + 'a {
        self.t.iter_in(&ars.t).filter_map(move |id| {
            let k = self.t.key(&ars.t, id);
            if k.is_sentinel() {
                None
            } else {
                let c = self.t.val(&ars.t, id);
                Some((k, c.p, c.n))
            }
        })
    }

    /// `MaxPos` computed from `T` alone by descending with `accpos` (no
    /// `TP`). Used by the ablation bench (`benches/ops.rs`) to quantify
    /// what the dedicated `TP` buys; also a cross-check in tests.
    pub(crate) fn max_pos_via_t(&self, ars: &EstimatorArenas, s: Score) -> NodeId {
        self.rightmost_pos(ars, self.t.root(), s).unwrap_or(self.neg_sentinel)
    }

    /// Rightmost node in `sub` with `key ≤ s` and `p > 0`, pruning
    /// positive-free subtrees via `accpos`.
    fn rightmost_pos(
        &self,
        ars: &EstimatorArenas,
        sub: Option<NodeId>,
        s: Score,
    ) -> Option<NodeId> {
        let v = sub?;
        if self.t.aug(&ars.t, v).pos == 0 {
            return None;
        }
        if self.t.key(&ars.t, v) > s {
            return self.rightmost_pos(ars, self.t.left(&ars.t, v), s);
        }
        // key(v) ≤ s: everything in the right subtree is > key(v) but may
        // exceed s; prefer it, then v itself, then the left subtree.
        self.rightmost_pos(ars, self.t.right(&ars.t, v), s)
            .or_else(|| if self.t.val(&ars.t, v).p > 0 { Some(v) } else { None })
            .or_else(|| self.rightmost_pos(ars, self.t.left(&ars.t, v), s))
    }

    /// Validate every §3 invariant (tests / property harness). Panics with
    /// a description on violation. `O(k)`.
    pub(crate) fn check_invariants(&self, ars: &EstimatorArenas) {
        self.t.check_invariants(&ars.t);
        self.tp.check_invariants(&ars.tp);
        // Totals match the root accumulators.
        let root = self.t.root().expect("sentinels always present");
        assert_eq!(self.t.aug(&ars.t, root).pos, self.total_pos, "accpos total");
        assert_eq!(self.t.aug(&ars.t, root).neg, self.total_neg, "accneg total");
        // Every positive node is in TP and P; TP/P contain nothing else
        // beyond the sentinels.
        let mut pos_nodes = 2; // sentinels
        for id in self.t.iter_in(&ars.t) {
            let k = self.t.key(&ars.t, id);
            let c = self.t.val(&ars.t, id);
            if k.is_sentinel() {
                assert_eq!((c.p, c.n), (0, 0), "sentinel with labels");
                continue;
            }
            assert!(c.p + c.n > 0, "empty node left in T");
            if c.p > 0 {
                pos_nodes += 1;
                let tp = self.tp.find(&ars.tp, k).expect("positive node missing from TP");
                assert_eq!(*self.tp.val(&ars.tp, tp), id, "TP maps to wrong T node");
                assert!(self.p.contains(&ars.p, id), "positive node missing from P");
            } else {
                assert!(self.tp.find(&ars.tp, k).is_none(), "non-positive node in TP");
                assert!(!self.p.contains(&ars.p, id), "non-positive node in P");
            }
        }
        assert_eq!(self.tp.len(), pos_nodes, "TP size");
        assert_eq!(self.p.len(), pos_nodes, "P size");
        // P is score-ascending and its gap counters match brute force.
        let cells: Vec<_> = self.p.iter_in(&ars.p).collect();
        assert_eq!(self.p.node(&ars.p, cells[0]), self.neg_sentinel, "P head sentinel");
        assert_eq!(
            self.p.node(&ars.p, *cells.last().unwrap()),
            self.pos_sentinel,
            "P tail sentinel"
        );
        for w in cells.windows(2) {
            let (a, b) = (w[0], w[1]);
            let (sa, sb) = (
                self.score(ars, self.p.node(&ars.p, a)),
                self.score(ars, self.p.node(&ars.p, b)),
            );
            assert!(sa < sb, "P not score-ascending");
            let (hp_a, hn_a) = self.head_stats(ars, sa);
            let (hp_b, hn_b) = self.head_stats(ars, sb);
            assert_eq!(self.p.gp(&ars.p, a), hp_b - hp_a, "gp(a;P) brute mismatch");
            assert_eq!(self.p.gn(&ars.p, a), hn_b - hn_a, "gn(a;P) brute mismatch");
            // In P specifically, gaps contain no other positive node.
            assert_eq!(
                self.p.gp(&ars.p, a),
                self.t.val(&ars.t, self.p.node(&ars.p, a)).p,
                "gp(a;P) ≠ p(a)"
            );
        }
        // Cell caches (key, p, n) coherent with the tree.
        for &c in &cells {
            let node = self.p.node(&ars.p, c);
            assert_eq!(self.p.key(&ars.p, c), self.score(ars, node).0, "P cache: stale key");
            let cnt = self.t.val(&ars.t, node);
            assert_eq!(self.p.cp(&ars.p, c), cnt.p, "P cache: stale p");
            assert_eq!(self.p.cn(&ars.p, c), cnt.n, "P cache: stale n");
        }
        assert_eq!(self.p.total_gp(&ars.p), self.total_pos, "P covers all positives");
        assert_eq!(self.p.total_gn(&ars.p), self.total_neg, "P covers all negatives");
    }
}

/// The bundled §3 structure (`T`, `TP`, `P`) with its own private
/// arenas — the self-contained form for standalone estimators, tests
/// and benches. Delegates to a [`SupportCore`]; the fleet uses cores
/// against shard-owned arenas.
#[derive(Clone, Debug)]
pub struct SupportTree {
    ars: EstimatorArenas,
    core: SupportCore,
}

impl Default for SupportTree {
    fn default() -> Self {
        Self::new()
    }
}

impl SupportTree {
    /// Fresh structure holding only the two sentinels.
    pub fn new() -> Self {
        let mut ars = EstimatorArenas::default();
        let core = SupportCore::new_in(&mut ars);
        SupportTree { ars, core }
    }

    /// Total positive labels in the window.
    #[inline]
    pub fn total_pos(&self) -> u64 {
        self.core.total_pos()
    }

    /// Total negative labels in the window.
    #[inline]
    pub fn total_neg(&self) -> u64 {
        self.core.total_neg()
    }

    /// Window size `k` (all entries).
    #[inline]
    pub fn len(&self) -> usize {
        self.core.len()
    }

    /// True when the window holds no entries (sentinels don't count).
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.core.is_empty()
    }

    /// Number of distinct-score nodes in `T`, sentinels included.
    #[inline]
    pub fn t_len(&self) -> usize {
        self.core.t_len()
    }

    /// The `−∞` sentinel node.
    #[inline]
    pub fn neg_sentinel(&self) -> NodeId {
        self.core.neg_sentinel()
    }

    /// The `+∞` sentinel node.
    #[inline]
    pub fn pos_sentinel(&self) -> NodeId {
        self.core.pos_sentinel()
    }

    /// Score of a `T` node.
    #[inline]
    pub fn score(&self, v: NodeId) -> Score {
        self.core.score(&self.ars, v)
    }

    /// Label counters of a `T` node.
    #[inline]
    pub fn counts(&self, v: NodeId) -> Counts {
        self.core.counts(&self.ars, v)
    }

    /// Read-only view of the positive list `P` (for `AddNext`-style
    /// consumers and checks).
    #[inline]
    pub fn p_list(&self) -> PListView<'_> {
        PListView { core: self.core.p, ar: &self.ars.p }
    }

    /// `MaxPos(s)` (paper §3.2): the positive node with the largest score
    /// `≤ s`, falling back to the `−∞` sentinel. Also returns its `P`
    /// cell. `O(log k)`.
    pub fn max_pos(&self, s: Score) -> (NodeId, CellId) {
        self.core.max_pos(&self.ars, s)
    }

    /// `HeadStats(s)` (Algorithm 1): cumulative counts below `s` in
    /// `O(log k)`.
    pub fn head_stats(&self, s: Score) -> (u64, u64) {
        self.core.head_stats(&self.ars, s)
    }

    /// `AddTreePos(s)` (Algorithm 3): insert a positive entry. Returns the
    /// node holding the score. `O(log k)`.
    pub fn add_pos(&mut self, s: Score) -> NodeId {
        self.core.add_pos(&mut self.ars, s)
    }

    /// `AddTreeNeg(s)` (§3.3): insert a negative entry. Returns the node.
    /// `O(log k)`.
    pub fn add_neg(&mut self, s: Score) -> NodeId {
        self.core.add_neg(&mut self.ars, s)
    }

    /// `RemoveTreePos(s)` (Algorithm 2): remove one positive entry with
    /// score `s` (must exist). `O(log k)`.
    pub fn remove_pos(&mut self, s: Score) {
        self.core.remove_pos(&mut self.ars, s);
    }

    /// `RemoveTreeNeg(s)` (§3.3): remove one negative entry with score `s`
    /// (must exist). `O(log k)`.
    pub fn remove_neg(&mut self, s: Score) {
        self.core.remove_neg(&mut self.ars, s);
    }

    /// Exact AUC by full in-order enumeration of `T` (Eq. 1); `O(k)`.
    pub fn exact_auc(&self) -> f64 {
        self.core.exact_auc(&self.ars)
    }

    /// Iterate `(score, p, n)` for all live non-sentinel nodes ascending.
    pub fn groups(&self) -> impl Iterator<Item = (Score, u64, u64)> + '_ {
        self.core.groups(&self.ars)
    }

    /// `MaxPos` computed from `T` alone by descending with `accpos` (no
    /// `TP`). Ablation / cross-check path.
    pub fn max_pos_via_t(&self, s: Score) -> NodeId {
        self.core.max_pos_via_t(&self.ars, s)
    }

    /// Validate every §3 invariant (tests / property harness). Panics with
    /// a description on violation. `O(k)`.
    pub fn check_invariants(&self) {
        self.core.check_invariants(&self.ars);
    }
}

/// Read-only view of a weighted list living in someone else's arena
/// (the positive list `P` as exposed by [`SupportTree::p_list`]).
#[derive(Clone, Copy)]
pub struct PListView<'a> {
    core: ListCore,
    ar: &'a CellArena,
}

impl<'a> PListView<'a> {
    /// Number of cells, sentinels included.
    #[inline]
    pub fn len(&self) -> usize {
        self.core.len()
    }

    /// True when no cells are present.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.core.is_empty()
    }

    /// First cell.
    #[inline]
    pub fn head(&self) -> Option<CellId> {
        self.core.head()
    }

    /// Last cell.
    #[inline]
    pub fn tail(&self) -> Option<CellId> {
        self.core.tail()
    }

    /// `next(u; L)`.
    #[inline]
    pub fn next(&self, c: CellId) -> Option<CellId> {
        self.core.next(self.ar, c)
    }

    /// `prev(u; L)`.
    #[inline]
    pub fn prev(&self, c: CellId) -> Option<CellId> {
        self.core.prev(self.ar, c)
    }

    /// Tree node this cell references.
    #[inline]
    pub fn node(&self, c: CellId) -> NodeId {
        self.core.node(self.ar, c)
    }

    /// Gap positive count `gp(u; L)`.
    #[inline]
    pub fn gp(&self, c: CellId) -> u64 {
        self.core.gp(self.ar, c)
    }

    /// Gap negative count `gn(u; L)`.
    #[inline]
    pub fn gn(&self, c: CellId) -> u64 {
        self.core.gn(self.ar, c)
    }

    /// Cached score of the cell's node.
    #[inline]
    pub fn key(&self, c: CellId) -> f64 {
        self.core.key(self.ar, c)
    }

    /// Cached `p(v)` of the cell's node.
    #[inline]
    pub fn cp(&self, c: CellId) -> u64 {
        self.core.cp(self.ar, c)
    }

    /// Cached `n(v)` of the cell's node.
    #[inline]
    pub fn cn(&self, c: CellId) -> u64 {
        self.core.cn(self.ar, c)
    }

    /// `O(1)` membership test.
    #[inline]
    pub fn contains(&self, node: NodeId) -> bool {
        self.core.contains(self.ar, node)
    }

    /// Cell holding `node`, if present.
    #[inline]
    pub fn cell_of(&self, node: NodeId) -> Option<CellId> {
        self.core.cell_of(self.ar, node)
    }

    /// Iterate cells front to back.
    pub fn iter(&self) -> Cells<'a> {
        self.core.iter_in(self.ar)
    }
}

// `T`, `TP` and `P` are all index-addressed arenas of plain data, so
// the bundled support structure is `Send` — the property the fleet's
// parallel executor needs to drain per-stream estimators on worker
// threads. A regression (e.g. an `Rc` cache sneaking into a hot path)
// fails compilation here, not at a distant executor call site.
const _: () = {
    const fn assert_send<T: Send>() {}
    assert_send::<SupportTree>();
    assert_send::<EstimatorArenas>();
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, gen_ops, Op, Pcg};

    fn s(v: f64) -> Score {
        Score(v)
    }

    #[test]
    fn fresh_tree_is_sentinels_only() {
        let t = SupportTree::new();
        assert!(t.is_empty());
        assert_eq!(t.t_len(), 2);
        assert_eq!(t.exact_auc(), 0.5);
        t.check_invariants();
    }

    #[test]
    fn head_stats_basics() {
        let mut t = SupportTree::new();
        t.add_pos(s(1.0));
        t.add_pos(s(1.0));
        t.add_neg(s(2.0));
        t.add_pos(s(3.0));
        t.add_neg(s(3.0));
        t.check_invariants();
        assert_eq!(t.head_stats(s(0.5)), (0, 0));
        assert_eq!(t.head_stats(s(1.0)), (0, 0));
        assert_eq!(t.head_stats(s(1.5)), (2, 0));
        assert_eq!(t.head_stats(s(2.5)), (2, 1));
        assert_eq!(t.head_stats(s(3.0)), (2, 1));
        assert_eq!(t.head_stats(s(9.0)), (3, 2));
    }

    #[test]
    fn max_pos_falls_back_to_sentinel() {
        let mut t = SupportTree::new();
        t.add_neg(s(1.0));
        let (v, _) = t.max_pos(s(5.0));
        assert_eq!(v, t.neg_sentinel());
        t.add_pos(s(2.0));
        let (v, _) = t.max_pos(s(5.0));
        assert_eq!(t.score(v), s(2.0));
        let (v, _) = t.max_pos(s(1.5));
        assert_eq!(v, t.neg_sentinel());
        t.check_invariants();
    }

    #[test]
    fn duplicate_scores_aggregate() {
        let mut t = SupportTree::new();
        for _ in 0..5 {
            t.add_pos(s(1.0));
        }
        for _ in 0..3 {
            t.add_neg(s(1.0));
        }
        assert_eq!(t.t_len(), 3); // one real node + sentinels
        let v = t.max_pos(s(1.0)).0;
        assert_eq!(t.counts(v), Counts { p: 5, n: 3 });
        assert_eq!(t.exact_auc(), 0.5); // all tied
        t.check_invariants();
    }

    #[test]
    fn perfect_and_reversed_auc() {
        let mut t = SupportTree::new();
        // positives low, negatives high → AUC 1 (paper's convention).
        for i in 0..10 {
            t.add_pos(s(f64::from(i)));
            t.add_neg(s(f64::from(i) + 100.0));
        }
        assert_eq!(t.exact_auc(), 1.0);
        t.check_invariants();
        let mut t = SupportTree::new();
        for i in 0..10 {
            t.add_neg(s(f64::from(i)));
            t.add_pos(s(f64::from(i) + 100.0));
        }
        assert_eq!(t.exact_auc(), 0.0);
    }

    #[test]
    fn remove_pos_demotes_and_deletes_nodes() {
        let mut t = SupportTree::new();
        t.add_pos(s(1.0));
        t.add_neg(s(1.0));
        t.add_pos(s(2.0));
        t.check_invariants();
        // Node 1.0 stays (still has a negative), leaves P/TP.
        t.remove_pos(s(1.0));
        t.check_invariants();
        assert_eq!(t.t_len(), 4);
        assert_eq!(t.max_pos(s(1.5)).0, t.neg_sentinel());
        // Node 2.0 disappears entirely.
        t.remove_pos(s(2.0));
        t.check_invariants();
        assert_eq!(t.t_len(), 3);
        assert_eq!(t.total_pos(), 0);
    }

    #[test]
    fn negative_gap_accounting_across_positive_removal() {
        let mut t = SupportTree::new();
        t.add_pos(s(1.0));
        t.add_neg(s(2.0));
        t.add_neg(s(3.0));
        t.add_pos(s(4.0));
        t.add_neg(s(5.0));
        t.check_invariants();
        // Removing the positive at 1.0 folds its gap (two negatives) into
        // the −∞ sentinel's gap.
        t.remove_pos(s(1.0));
        t.check_invariants();
        let head = t.p_list().head().unwrap();
        assert_eq!(t.p_list().gn(head), 2);
    }

    #[test]
    #[should_panic(expected = "not present")]
    fn remove_missing_score_panics() {
        let mut t = SupportTree::new();
        t.remove_pos(s(1.0));
    }

    #[test]
    #[should_panic(expected = "no positive labels")]
    fn remove_wrong_label_panics() {
        let mut t = SupportTree::new();
        t.add_neg(s(1.0));
        t.remove_pos(s(1.0));
    }

    #[test]
    fn exact_auc_matches_naive_small() {
        // Hand-checked: P = {0.1, 0.5}, N = {0.3, 0.5}.
        // Pairs (p, n): (0.1 vs 0.3) correct, (0.1 vs 0.5) correct,
        // (0.5 vs 0.3) wrong, (0.5 vs 0.5) tie → (2 + 0.5) / 4.
        let mut t = SupportTree::new();
        t.add_pos(s(0.1));
        t.add_pos(s(0.5));
        t.add_neg(s(0.3));
        t.add_neg(s(0.5));
        assert_eq!(t.exact_auc(), 2.5 / 4.0);
    }

    #[test]
    fn max_pos_via_t_matches_tp() {
        check(0x51AB, 30, |rng| {
            let mut t = SupportTree::new();
            let ops = gen_ops(rng, 120, 40, Some(16));
            for op in ops {
                match op {
                    Op::Insert { score, pos: true } => {
                        t.add_pos(s(score));
                    }
                    Op::Insert { score, pos: false } => {
                        t.add_neg(s(score));
                    }
                    Op::Remove { score, pos: true } => t.remove_pos(s(score)),
                    Op::Remove { score, pos: false } => t.remove_neg(s(score)),
                }
                for q in [0.0, 0.25, 0.5, 0.75, 1.0, rng.uniform()] {
                    assert_eq!(
                        t.max_pos(s(q)).0,
                        t.max_pos_via_t(s(q)),
                        "MaxPos disagreement at {q}"
                    );
                }
            }
        });
    }

    #[test]
    fn property_invariants_hold_under_random_ops() {
        for grid in [Some(8), Some(64), None] {
            check(0x7EE7 ^ grid.unwrap_or(0), 25, |rng| {
                let mut t = SupportTree::new();
                let len = 150 + rng.below(100) as usize;
                let ops = gen_ops(rng, len, 50, grid);
                for (i, op) in ops.iter().enumerate() {
                    match *op {
                        Op::Insert { score, pos: true } => {
                            t.add_pos(s(score));
                        }
                        Op::Insert { score, pos: false } => {
                            t.add_neg(s(score));
                        }
                        Op::Remove { score, pos: true } => t.remove_pos(s(score)),
                        Op::Remove { score, pos: false } => t.remove_neg(s(score)),
                    }
                    if i % 10 == 0 {
                        t.check_invariants();
                    }
                }
                t.check_invariants();
            });
        }
    }

    #[test]
    fn head_stats_matches_brute_force() {
        check(0xB0B, 20, |rng| {
            let mut t = SupportTree::new();
            let mut entries: Vec<(f64, bool)> = Vec::new();
            for _ in 0..100 {
                let score = rng.below(32) as f64 / 32.0;
                let pos = rng.chance(0.5);
                if pos {
                    t.add_pos(s(score));
                } else {
                    t.add_neg(s(score));
                }
                entries.push((score, pos));
            }
            for _ in 0..20 {
                let q = rng.uniform();
                let hp = entries.iter().filter(|(sc, p)| *sc < q && *p).count() as u64;
                let hn = entries.iter().filter(|(sc, p)| *sc < q && !*p).count() as u64;
                assert_eq!(t.head_stats(s(q)), (hp, hn));
            }
        });
    }

    #[test]
    fn alternating_churn_keeps_structures_tight() {
        // FIFO window churn: the workload of the actual system.
        let mut t = SupportTree::new();
        let mut rng = Pcg::seed(99);
        let mut window: std::collections::VecDeque<(f64, bool)> = Default::default();
        for i in 0..2000 {
            let score = rng.below(128) as f64 / 128.0;
            let pos = rng.chance(0.3);
            if pos {
                t.add_pos(s(score));
            } else {
                t.add_neg(s(score));
            }
            window.push_back((score, pos));
            if window.len() > 100 {
                let (score, pos) = window.pop_front().unwrap();
                if pos {
                    t.remove_pos(s(score));
                } else {
                    t.remove_neg(s(score));
                }
            }
            if i % 250 == 0 {
                t.check_invariants();
            }
        }
        assert_eq!(t.len(), 100);
        t.check_invariants();
    }

    #[test]
    fn free_in_returns_every_slot() {
        let mut ars = EstimatorArenas::default();
        let mut core = SupportCore::new_in(&mut ars);
        let mut rng = Pcg::seed(7);
        for _ in 0..200 {
            let sc = s(rng.below(32) as f64 / 32.0);
            if rng.chance(0.5) {
                core.add_pos(&mut ars, sc);
            } else {
                core.add_neg(&mut ars, sc);
            }
        }
        core.check_invariants(&ars);
        core.free_in(&mut ars);
        // Every slot is back on a free list: reset (which asserts
        // exactly that) must succeed and leave zero bytes live.
        ars.reset();
        assert_eq!(ars.live_bytes(), 0);
        // The bundle is reusable afterwards.
        let core = SupportCore::new_in(&mut ars);
        assert_eq!(core.t_len(), 2);
        core.check_invariants(&ars);
    }
}
