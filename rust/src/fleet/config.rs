//! Fleet and per-stream configuration.
//!
//! Every stream in an [`AucFleet`](super::AucFleet) owns an independent
//! sliding window; the fleet applies [`FleetConfig::stream_defaults`]
//! to streams it has never seen and per-stream overrides registered
//! with [`AucFleet::configure_stream`](super::AucFleet::configure_stream)
//! otherwise. All configs are plain `Copy` data so the hot ingestion
//! path never clones heap state.

use crate::coordinator::AucMonitor;

/// Drift-monitor parameters for one stream (see [`AucMonitor::new`] for
/// the λ-vs-window guidance).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MonitorConfig {
    /// EWMA decay factor for the baseline (weight of the new sample).
    pub lambda: f64,
    /// Absolute AUC margin below baseline that counts as degradation.
    pub margin: f64,
    /// Consecutive degraded observations before the alarm fires.
    pub patience: u32,
    /// Observations before the baseline is trusted.
    pub warmup: u32,
}

impl Default for MonitorConfig {
    fn default() -> Self {
        // Tuned for the default stream window of 500: baseline time
        // constant ≫ window, margin above windowed-estimate noise.
        MonitorConfig { lambda: 0.001, margin: 0.08, patience: 100, warmup: 500 }
    }
}

impl MonitorConfig {
    /// Instantiate the monitor.
    pub fn build(&self) -> AucMonitor {
        AucMonitor::new(self.lambda, self.margin, self.patience, self.warmup)
    }
}

/// Per-stream estimator configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StreamConfig {
    /// Sliding-window capacity `k`.
    pub window: usize,
    /// Approximation parameter `ε ≥ 0` (`|ãuc − auc| ≤ ε·auc/2`).
    pub epsilon: f64,
    /// Drift monitor; `None` disables monitoring for the stream (saves
    /// one `O(|C|)` AUC read per update).
    pub monitor: Option<MonitorConfig>,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig { window: 500, epsilon: 0.05, monitor: Some(MonitorConfig::default()) }
    }
}

impl StreamConfig {
    /// Window/ε constructor with default monitoring.
    pub fn new(window: usize, epsilon: f64) -> Self {
        StreamConfig { window, epsilon, ..Default::default() }
    }

    /// Disable the drift monitor.
    pub fn without_monitor(mut self) -> Self {
        self.monitor = None;
        self
    }

    /// Replace the drift monitor parameters.
    pub fn with_monitor(mut self, monitor: MonitorConfig) -> Self {
        self.monitor = Some(monitor);
        self
    }
}

/// Fleet-wide configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FleetConfig {
    /// Shard count; rounded up to the next power of two, minimum 1.
    /// Streams are distributed by a mixed hash of their id, so shard
    /// occupancy stays balanced regardless of id patterns.
    pub shards: usize,
    /// Ingestion worker threads for batched ingestion and aggregate
    /// queries; `0` and `1` both mean the serial inline path. Worker
    /// count never changes results, only wall-clock (the executor's
    /// determinism contract), so it is safe to tune freely. More workers
    /// than busy shards is wasteful — the executor caps participation at
    /// one worker per claimable shard.
    pub workers: usize,
    /// Use the persistent worker pool (threads spawned once per fleet,
    /// parked between batches) for batch drains. With `false`, parallel
    /// drains fall back to a `std::thread::scope` per batch — the PR-2
    /// baseline, kept for comparison benchmarks. Irrelevant when
    /// `workers ≤ 1`. Execution strategy never changes results.
    pub pool: bool,
    /// Pipeline batches: `push_batch` returns as soon as the drain is
    /// handed to the pool, so the caller buckets/generates the next
    /// batch while workers drain the previous one. Results stay
    /// bit-identical — every read synchronizes on the in-flight batch
    /// first. Effective only with `pool` and `workers ≥ 2`.
    pub pipeline: bool,
    /// Scale the active worker count to the observed batch size: a
    /// batch engages roughly one worker per
    /// [`ADAPTIVE_EVENTS_PER_WORKER`](super::ADAPTIVE_EVENTS_PER_WORKER)
    /// events (capped at `workers`), and a batch small enough for one
    /// worker skips the pool dispatch entirely and drains inline — so
    /// trickle traffic stops paying the full parallel submission cost.
    /// Worker count never changes results, so this only moves
    /// wall-clock. Off by default (fixed worker count).
    pub adaptive: bool,
    /// Configuration applied to streams without an explicit override.
    pub stream_defaults: StreamConfig,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            shards: 64,
            workers: 1,
            pool: true,
            pipeline: false,
            adaptive: false,
            stream_defaults: StreamConfig::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builders_compose() {
        let c = StreamConfig::new(200, 0.1);
        assert_eq!(c.window, 200);
        assert_eq!(c.epsilon, 0.1);
        assert!(c.monitor.is_some());
        assert!(c.without_monitor().monitor.is_none());
        let m = MonitorConfig { lambda: 0.01, margin: 0.1, patience: 5, warmup: 10 };
        assert_eq!(StreamConfig::new(10, 0.5).with_monitor(m).monitor, Some(m));
    }

    #[test]
    fn fleet_defaults_prefer_the_pool_without_pipelining() {
        let c = FleetConfig::default();
        assert_eq!(c.workers, 1);
        assert!(c.pool, "pooled execution is the default strategy");
        assert!(!c.pipeline, "pipelining is opt-in");
        assert!(!c.adaptive, "adaptive worker scaling is opt-in");
    }

    #[test]
    fn monitor_config_builds() {
        let m = MonitorConfig::default().build();
        assert!(!m.is_alarmed());
        assert_eq!(m.baseline(), 0.0);
    }
}
