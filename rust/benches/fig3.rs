//! Bench target regenerating Figure 3: per-event cost versus window
//! size, exact baseline against ε ∈ {0.01, 0.1} (miniboone).
//!
//! `cargo bench --bench fig3 [-- --events N]`
//!
//! Expected shape (paper §6): the speed-up grows with k; the paper
//! reports ≈17× at k = 10⁴, ε = 0.1 (C++/2019 laptop — the ratio, not
//! the absolute time, is the reproduction target).

use streamauc::experiments::{fig3, ExpConfig};

fn main() {
    let mut cfg = ExpConfig { events: 40_000, ..Default::default() };
    let args: Vec<String> = std::env::args().collect();
    if let Some(i) = args.iter().position(|a| a == "--events") {
        cfg.events = args[i + 1].parse().expect("--events N");
    }
    println!("{}", fig3::run(cfg).render());
}
