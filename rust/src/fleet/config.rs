//! Fleet and per-stream configuration.
//!
//! Every stream in an [`AucFleet`](super::AucFleet) owns an independent
//! sliding window; the fleet applies [`FleetConfig::stream_defaults`]
//! to streams it has never seen and per-stream overrides registered
//! with [`AucFleet::configure_stream`](super::AucFleet::configure_stream)
//! otherwise. All configs are plain `Copy` data so the hot ingestion
//! path never clones heap state.

use crate::coordinator::window::Window;
use crate::coordinator::{ApproxAuc, AucEstimator, AucMonitor, MaintainedExactAuc};

/// Which estimator a stream runs behind its sliding window.
///
/// Both kinds satisfy the same O(1)-read contract (`DESIGN.md`
/// §Estimators), so exactness-critical and approximate streams coexist
/// in one fleet — sketches, snapshots, aggregates and the digest
/// determinism contract are estimator-agnostic.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EstimatorKind {
    /// The paper's `(1+ε)`-compressed estimator:
    /// `|ãuc − auc| ≤ ε·auc/2`, `O((log k)/ε)` update, smallest
    /// footprint (`|C| ∈ O((log k)/ε)` cells).
    Approx {
        /// Approximation parameter `ε ≥ 0`.
        epsilon: f64,
    },
    /// Tree-maintained exact AUC (Tatti 2021): no ε at all, `O(log k)`
    /// update, one tree node per distinct score. Pick it for streams
    /// where the estimate feeds decisions that cannot tolerate even the
    /// ε/2 slack; pay ~`O(k)` memory per window in exchange.
    ExactMaintained,
}

impl EstimatorKind {
    /// Instantiate the per-stream estimator.
    pub(crate) fn build(self) -> FleetEstimator {
        match self {
            EstimatorKind::Approx { epsilon } => {
                FleetEstimator::Approx(ApproxAuc::new(epsilon))
            }
            EstimatorKind::ExactMaintained => {
                FleetEstimator::Exact(MaintainedExactAuc::new())
            }
        }
    }
}

/// The estimator actually held by a fleet stream: either kind behind
/// one enum so `StreamState` stays a single concrete type (no dyn
/// dispatch on the ingest hot path — one match, both arms inlinable).
#[derive(Clone, Debug)]
pub enum FleetEstimator {
    /// `(1+ε)`-compressed approximate estimator.
    Approx(ApproxAuc),
    /// Tree-maintained exact estimator.
    Exact(MaintainedExactAuc),
}

impl FleetEstimator {
    /// Size of the structure the estimator maintains beyond the window
    /// itself: compressed-list cells for [`ApproxAuc`], distinct-score
    /// tree nodes for [`MaintainedExactAuc`]. Feeds
    /// `StreamSnapshot::compressed_len`.
    pub fn footprint(&self) -> usize {
        match self {
            FleetEstimator::Approx(e) => e.compressed_len(),
            FleetEstimator::Exact(e) => e.distinct_scores(),
        }
    }
}

impl AucEstimator for FleetEstimator {
    fn insert(&mut self, score: f64, pos: bool) {
        match self {
            FleetEstimator::Approx(e) => e.insert(score, pos),
            FleetEstimator::Exact(e) => e.insert(score, pos),
        }
    }

    fn remove(&mut self, score: f64, pos: bool) {
        match self {
            FleetEstimator::Approx(e) => e.remove(score, pos),
            FleetEstimator::Exact(e) => e.remove(score, pos),
        }
    }

    fn auc(&self) -> f64 {
        match self {
            FleetEstimator::Approx(e) => e.auc(),
            FleetEstimator::Exact(e) => e.auc(),
        }
    }

    fn len(&self) -> usize {
        match self {
            FleetEstimator::Approx(e) => e.len(),
            FleetEstimator::Exact(e) => e.len(),
        }
    }
}

// Stream windows over this enum drain on the fleet's worker threads.
const _: () = {
    const fn assert_send<T: Send>() {}
    assert_send::<FleetEstimator>();
    assert_send::<Window<FleetEstimator>>();
};

/// Drift-monitor parameters for one stream (see [`AucMonitor::new`] for
/// the λ-vs-window guidance).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MonitorConfig {
    /// EWMA decay factor for the baseline (weight of the new sample).
    pub lambda: f64,
    /// Absolute AUC margin below baseline that counts as degradation.
    pub margin: f64,
    /// Consecutive degraded observations before the alarm fires.
    pub patience: u32,
    /// Observations before the baseline is trusted.
    pub warmup: u32,
}

impl Default for MonitorConfig {
    fn default() -> Self {
        // Tuned for the default stream window of 500: baseline time
        // constant ≫ window, margin above windowed-estimate noise.
        MonitorConfig { lambda: 0.001, margin: 0.08, patience: 100, warmup: 500 }
    }
}

impl MonitorConfig {
    /// Instantiate the monitor.
    pub fn build(&self) -> AucMonitor {
        AucMonitor::new(self.lambda, self.margin, self.patience, self.warmup)
    }
}

/// Per-stream estimator configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StreamConfig {
    /// Sliding-window capacity `k`.
    pub window: usize,
    /// Which estimator backs the window (approximate with its ε, or
    /// tree-maintained exact).
    pub estimator: EstimatorKind,
    /// Drift monitor; `None` disables monitoring for the stream (saves
    /// one `O(1)` AUC read per update).
    pub monitor: Option<MonitorConfig>,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            window: 500,
            estimator: EstimatorKind::Approx { epsilon: 0.05 },
            monitor: Some(MonitorConfig::default()),
        }
    }
}

impl StreamConfig {
    /// Window/ε constructor with default monitoring (the approximate
    /// estimator — the fleet-scale default).
    pub fn new(window: usize, epsilon: f64) -> Self {
        StreamConfig { window, estimator: EstimatorKind::Approx { epsilon }, ..Default::default() }
    }

    /// Exact-maintained constructor with default monitoring, for
    /// exactness-critical streams.
    pub fn exact(window: usize) -> Self {
        StreamConfig { window, estimator: EstimatorKind::ExactMaintained, ..Default::default() }
    }

    /// The ε of an approximate stream; `None` for exact-maintained.
    pub fn epsilon(&self) -> Option<f64> {
        match self.estimator {
            EstimatorKind::Approx { epsilon } => Some(epsilon),
            EstimatorKind::ExactMaintained => None,
        }
    }

    /// Replace the estimator choice.
    pub fn with_estimator(mut self, estimator: EstimatorKind) -> Self {
        self.estimator = estimator;
        self
    }

    /// Disable the drift monitor.
    pub fn without_monitor(mut self) -> Self {
        self.monitor = None;
        self
    }

    /// Replace the drift monitor parameters.
    pub fn with_monitor(mut self, monitor: MonitorConfig) -> Self {
        self.monitor = Some(monitor);
        self
    }
}

/// Fleet-wide configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FleetConfig {
    /// Shard count; rounded up to the next power of two, minimum 1.
    /// Streams are distributed by a mixed hash of their id, so shard
    /// occupancy stays balanced regardless of id patterns.
    pub shards: usize,
    /// Ingestion worker threads for batched ingestion and aggregate
    /// queries; `0` and `1` both mean the serial inline path. Worker
    /// count never changes results, only wall-clock (the executor's
    /// determinism contract), so it is safe to tune freely. More workers
    /// than busy shards is wasteful — the executor caps participation at
    /// one worker per claimable shard.
    pub workers: usize,
    /// Use the persistent worker pool (threads spawned once per fleet,
    /// parked between batches) for batch drains. With `false`, parallel
    /// drains fall back to a `std::thread::scope` per batch — the PR-2
    /// baseline, kept for comparison benchmarks. Irrelevant when
    /// `workers ≤ 1`. Execution strategy never changes results.
    pub pool: bool,
    /// Pipeline batches: `push_batch` returns as soon as the drain is
    /// handed to the pool, so the caller buckets/generates the next
    /// batch while workers drain the previous one. Results stay
    /// bit-identical — every read synchronizes on the in-flight batch
    /// first. Effective only with `pool` and `workers ≥ 2`.
    pub pipeline: bool,
    /// Scale the active worker count to the observed batch size: a
    /// batch engages roughly one worker per
    /// [`ADAPTIVE_EVENTS_PER_WORKER`](super::ADAPTIVE_EVENTS_PER_WORKER)
    /// events (capped at `workers`), and a batch small enough for one
    /// worker skips the pool dispatch entirely and drains inline — so
    /// trickle traffic stops paying the full parallel submission cost.
    /// Worker count never changes results, so this only moves
    /// wall-clock. Off by default (fixed worker count).
    pub adaptive: bool,
    /// Configuration applied to streams without an explicit override.
    pub stream_defaults: StreamConfig,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            shards: 64,
            workers: 1,
            pool: true,
            pipeline: false,
            adaptive: false,
            stream_defaults: StreamConfig::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builders_compose() {
        let c = StreamConfig::new(200, 0.1);
        assert_eq!(c.window, 200);
        assert_eq!(c.estimator, EstimatorKind::Approx { epsilon: 0.1 });
        assert_eq!(c.epsilon(), Some(0.1));
        assert!(c.monitor.is_some());
        assert!(c.without_monitor().monitor.is_none());
        let m = MonitorConfig { lambda: 0.01, margin: 0.1, patience: 5, warmup: 10 };
        assert_eq!(StreamConfig::new(10, 0.5).with_monitor(m).monitor, Some(m));
        let e = StreamConfig::exact(64);
        assert_eq!(e.estimator, EstimatorKind::ExactMaintained);
        assert_eq!(e.epsilon(), None);
        assert!(e.monitor.is_some());
        let swapped = c.with_estimator(EstimatorKind::ExactMaintained);
        assert_eq!(swapped.estimator, EstimatorKind::ExactMaintained);
        assert_eq!(swapped.window, 200);
    }

    #[test]
    fn estimator_kinds_build_their_estimators() {
        match (EstimatorKind::Approx { epsilon: 0.25 }).build() {
            FleetEstimator::Approx(e) => assert_eq!(e.epsilon(), 0.25),
            other => panic!("expected approx, built {other:?}"),
        }
        let mut exact = EstimatorKind::ExactMaintained.build();
        assert!(matches!(exact, FleetEstimator::Exact(_)));
        exact.insert(0.2, true);
        exact.insert(0.8, false);
        assert_eq!(exact.auc(), 1.0);
        assert_eq!(exact.footprint(), 2);
    }

    #[test]
    fn fleet_defaults_prefer_the_pool_without_pipelining() {
        let c = FleetConfig::default();
        assert_eq!(c.workers, 1);
        assert!(c.pool, "pooled execution is the default strategy");
        assert!(!c.pipeline, "pipelining is opt-in");
        assert!(!c.adaptive, "adaptive worker scaling is opt-in");
    }

    #[test]
    fn monitor_config_builds() {
        let m = MonitorConfig::default().build();
        assert!(!m.is_alarmed());
        assert_eq!(m.baseline(), 0.0);
    }
}
