//! The serving front-end: a [`FleetServer`] owns an [`AucFleet`]
//! behind a mutex, answers read queries from any number of
//! connections, and pushes sketch deltas to subscribers after every
//! ingestion drain.
//!
//! One listener port speaks both protocols. The first byte of a
//! connection routes it: [`wire::MAGIC`]'s `0xAB` can never begin an
//! HTTP method token, so anything else is parsed as HTTP/1.1
//! (`GET`-only, keep-alive, `Content-Length`-framed JSON bodies)
//! and a `0xAB` preamble opens a length-prefixed binary session.
//!
//! **Wire ≡ in-process.** Handlers call the exact same [`AucFleet`]
//! query methods a linked-in caller would, under the same lock, and
//! the codecs (`super::json`, `super::wire`) are lossless for every
//! served type — so a decoded response is bit-identical to the
//! in-process answer at the same instant. `rust/tests/serve.rs` and
//! the executor digest harness enforce this end to end.
//!
//! Malformed requests never panic the fleet: parameters are validated
//! at the surface ([`validate`]) and rejected with HTTP 400 or a
//! [`wire::STATUS_ERR`] frame — notably `bins=0` histograms (the
//! in-process methods assert) and non-finite `count_below` thresholds
//! (JSON cannot carry them back).

use std::io::{self, BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};

use super::{json, wire};
use crate::fleet::{AucFleet, FleetSketch};

/// A query decoded from either protocol; both surfaces funnel into
/// the same fleet calls so their answers cannot diverge.
enum Request {
    Snapshot,
    Aggregate,
    TopK(usize),
    CountBelow(f64),
    AucHistogram(usize),
    ScoreHistogram(usize),
    Subscribe,
}

/// Surface validation — everything that would panic or be
/// unserializable in-process is rejected here with a client error.
fn validate(req: &Request) -> Result<(), String> {
    match *req {
        Request::CountBelow(t) if !t.is_finite() => {
            Err(format!("count_below: threshold must be finite, got {t}"))
        }
        Request::AucHistogram(0) => Err("auc_histogram: bins must be >= 1".to_string()),
        Request::ScoreHistogram(0) => Err("score_histogram: bins must be >= 1".to_string()),
        _ => Ok(()),
    }
}

fn answer_json(fleet: &AucFleet, req: &Request) -> String {
    match *req {
        Request::Snapshot => json::snapshot_to_json(&fleet.snapshot()),
        Request::Aggregate => json::aggregate_to_json(&fleet.aggregate()),
        Request::TopK(k) => json::top_k_to_json(&fleet.top_k_worst(k)),
        Request::CountBelow(t) => json::count_below_to_json(t, fleet.count_below(t)),
        Request::AucHistogram(b) => json::auc_histogram_to_json(&fleet.auc_histogram(b)),
        Request::ScoreHistogram(b) => json::score_histogram_to_json(&fleet.score_histogram(b)),
        Request::Subscribe => unreachable!("subscribe is handled by the session loop"),
    }
}

fn answer_binary(fleet: &AucFleet, req: &Request) -> Vec<u8> {
    match *req {
        Request::Snapshot => wire::encode_snapshot(&fleet.snapshot()),
        Request::Aggregate => wire::encode_aggregate(&fleet.aggregate()),
        Request::TopK(k) => wire::encode_top_k(&fleet.top_k_worst(k)),
        Request::CountBelow(t) => wire::encode_count_below(t, fleet.count_below(t)),
        Request::AucHistogram(b) => wire::encode_auc_histogram(&fleet.auc_histogram(b)),
        Request::ScoreHistogram(b) => wire::encode_score_histogram(&fleet.score_histogram(b)),
        Request::Subscribe => unreachable!("subscribe is handled by the session loop"),
    }
}

// ---------------------------------------------------------------------
// Shared state
// ---------------------------------------------------------------------

enum Proto {
    Http,
    Binary,
}

struct Subscriber {
    stream: TcpStream,
    proto: Proto,
}

impl Subscriber {
    /// Push one delta; a `false` return drops the subscriber.
    fn send(&mut self, json_line: &str, bin_payload: &[u8]) -> bool {
        let r = match self.proto {
            Proto::Http => self
                .stream
                .write_all(json_line.as_bytes())
                .and_then(|()| self.stream.write_all(b"\n")),
            Proto::Binary => wire::write_frame(&mut self.stream, wire::OP_DELTA, bin_payload),
        };
        r.is_ok()
    }
}

/// Publisher state: the last broadcast sketch and its sequence number.
/// Lock order is `pub_state` → `subs` in both the publish and the
/// subscribe paths, which is what makes the baseline/delta hand-off
/// gapless: a subscriber's baseline is written while `pub_state` is
/// held, so no delta can slip in between the baseline and the
/// subscriber joining the broadcast list.
struct PubState {
    seq: u64,
    last: FleetSketch,
}

struct Shared {
    fleet: Mutex<AucFleet>,
    subs: Mutex<Vec<Subscriber>>,
    pub_state: Mutex<PubState>,
    stop: AtomicBool,
}

const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<Shared>();
};

// ---------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------

/// A running serving front-end over one [`AucFleet`].
///
/// The server is `Sync`: ingestion goes through `&self`
/// ([`FleetServer::ingest_batch_at`]) while the acceptor thread
/// answers queries concurrently, so one thread can drive the event
/// feed while clients read. Dropping the server stops the acceptor
/// and disconnects subscribers.
pub struct FleetServer {
    shared: Arc<Shared>,
    addr: SocketAddr,
    acceptor: Option<JoinHandle<()>>,
}

impl FleetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start accepting connections over `fleet`.
    pub fn start(fleet: AucFleet, addr: &str) -> io::Result<FleetServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let baseline = fleet.sketch_state();
        let shared = Arc::new(Shared {
            fleet: Mutex::new(fleet),
            subs: Mutex::new(Vec::new()),
            pub_state: Mutex::new(PubState { seq: 0, last: baseline }),
            stop: AtomicBool::new(false),
        });
        let accept_shared = Arc::clone(&shared);
        let acceptor = thread::Builder::new()
            .name("fleet-serve-accept".to_string())
            .spawn(move || {
                for conn in listener.incoming() {
                    if accept_shared.stop.load(Ordering::Acquire) {
                        break;
                    }
                    let Ok(stream) = conn else { continue };
                    let conn_shared = Arc::clone(&accept_shared);
                    // Handlers are detached: they exit when their
                    // connection closes, and shutdown disconnects
                    // subscribers by clearing the broadcast list.
                    let _ = thread::Builder::new()
                        .name("fleet-serve-conn".to_string())
                        .spawn(move || {
                            let _ = handle_connection(stream, &conn_shared);
                        });
                }
            })?;
        Ok(FleetServer { shared, addr: local, acceptor: Some(acceptor) })
    }

    /// The bound address (with the real port when bound to `:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Feed a batch at the fleet's internal clock, then publish the
    /// resulting sketch delta to subscribers.
    pub fn ingest_batch(&self, batch: &[(u64, f64, bool)]) {
        let next = {
            let mut fleet = self.shared.fleet.lock().expect("fleet lock");
            fleet.push_batch(batch);
            // Waits for the drain — per-drain deltas are the contract.
            fleet.sketch_state()
        };
        self.publish(next);
    }

    /// Feed a batch at an explicit clock, then publish the delta.
    pub fn ingest_batch_at(&self, batch: &[(u64, f64, bool)], at: u64) {
        let next = {
            let mut fleet = self.shared.fleet.lock().expect("fleet lock");
            fleet.push_batch_at(batch, at);
            fleet.sketch_state()
        };
        self.publish(next);
    }

    /// Run `f` against the fleet under the serving lock — the
    /// in-process answer a wire response must be bit-identical to.
    pub fn with_fleet<R>(&self, f: impl FnOnce(&AucFleet) -> R) -> R {
        f(&self.shared.fleet.lock().expect("fleet lock"))
    }

    /// Run `f` against the fleet mutably (eviction, reconfiguration).
    /// No delta is published; pair with [`FleetServer::ingest_batch`]
    /// or rely on the next drain to refresh subscribers.
    pub fn with_fleet_mut<R>(&self, f: impl FnOnce(&mut AucFleet) -> R) -> R {
        f(&mut self.shared.fleet.lock().expect("fleet lock"))
    }

    /// Currently attached subscribers.
    pub fn subscriber_count(&self) -> usize {
        self.shared.subs.lock().expect("subscriber list").len()
    }

    /// The last published `(seq, sketch)` — what an up-to-date
    /// subscriber has reconstructed.
    pub fn last_published(&self) -> (u64, FleetSketch) {
        let st = self.shared.pub_state.lock().expect("publisher state");
        (st.seq, st.last.clone())
    }

    fn publish(&self, next: FleetSketch) {
        let mut st = self.shared.pub_state.lock().expect("publisher state");
        if st.last == next {
            return; // quiet drain: subscribers owe nothing
        }
        st.seq += 1;
        let json_line = json::delta_to_json(st.seq, &st.last, &next);
        let bin_payload = wire::encode_delta(st.seq, &st.last, &next);
        st.last = next;
        let mut subs = self.shared.subs.lock().expect("subscriber list");
        subs.retain_mut(|sub| sub.send(&json_line, &bin_payload));
    }

    /// Stop accepting, join the acceptor, and drop all subscribers.
    /// Idempotent; also runs on drop.
    pub fn shutdown(&mut self) {
        if self.shared.stop.swap(true, Ordering::AcqRel) {
            return;
        }
        // Unblock the blocking accept() with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(handle) = self.acceptor.take() {
            let _ = handle.join();
        }
        self.shared.subs.lock().expect("subscriber list").clear();
    }
}

impl Drop for FleetServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ---------------------------------------------------------------------
// Connection handling
// ---------------------------------------------------------------------

fn handle_connection(stream: TcpStream, shared: &Shared) -> io::Result<()> {
    let mut first = [0u8; 1];
    if stream.peek(&mut first)? == 0 {
        return Ok(()); // closed before sending anything
    }
    if first[0] == wire::MAGIC[0] {
        handle_binary(stream, shared)
    } else {
        handle_http(stream, shared)
    }
}

fn handle_binary(mut stream: TcpStream, shared: &Shared) -> io::Result<()> {
    let mut magic = [0u8; 4];
    stream.read_exact(&mut magic)?;
    if magic != wire::MAGIC {
        return wire::write_frame(&mut stream, wire::STATUS_ERR, b"bad magic");
    }
    loop {
        let Ok((op, payload)) = wire::read_frame(&mut stream) else {
            return Ok(()); // client hung up
        };
        match binary_request(op, &payload) {
            Ok(Request::Subscribe) => return subscribe_binary(stream, shared),
            Ok(req) => {
                let body = {
                    let fleet = shared.fleet.lock().expect("fleet lock");
                    answer_binary(&fleet, &req)
                };
                wire::write_frame(&mut stream, wire::STATUS_OK, &body)?;
            }
            Err(msg) => wire::write_frame(&mut stream, wire::STATUS_ERR, msg.as_bytes())?,
        }
    }
}

fn binary_request(op: u8, payload: &[u8]) -> Result<Request, String> {
    let mut c = wire::Cursor::new(payload);
    let req = match op {
        wire::OP_SNAPSHOT => Request::Snapshot,
        wire::OP_AGGREGATE => Request::Aggregate,
        wire::OP_TOP_K => Request::TopK(c.u32()? as usize),
        wire::OP_COUNT_BELOW => Request::CountBelow(c.f64()?),
        wire::OP_AUC_HISTOGRAM => Request::AucHistogram(c.u32()? as usize),
        wire::OP_SCORE_HISTOGRAM => Request::ScoreHistogram(c.u32()? as usize),
        wire::OP_SUBSCRIBE => Request::Subscribe,
        other => return Err(format!("unknown opcode {other}")),
    };
    c.done()?;
    validate(&req)?;
    Ok(req)
}

fn subscribe_binary(mut stream: TcpStream, shared: &Shared) -> io::Result<()> {
    // Hold pub_state across baseline write + subscriber insertion so
    // the first delta a subscriber sees is seq(baseline) + 1.
    let st = shared.pub_state.lock().expect("publisher state");
    let payload = wire::encode_sketch(st.seq, &st.last);
    wire::write_frame(&mut stream, wire::STATUS_OK, &payload)?;
    shared
        .subs
        .lock()
        .expect("subscriber list")
        .push(Subscriber { stream, proto: Proto::Binary });
    drop(st);
    Ok(())
}

enum HttpError {
    /// 400 with a message.
    Bad(String),
    /// 404 for an unknown path.
    NotFound(String),
}

fn handle_http(stream: TcpStream, shared: &Shared) -> io::Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    loop {
        let Some((method, target, close)) = read_http_request(&mut reader)? else {
            return Ok(()); // client hung up between requests
        };
        match http_request(&method, &target) {
            Ok(Request::Subscribe) => return subscribe_http(stream, shared),
            Ok(req) => {
                let body = {
                    let fleet = shared.fleet.lock().expect("fleet lock");
                    answer_json(&fleet, &req)
                };
                write_http(&mut stream, 200, &body, close)?;
            }
            Err(HttpError::NotFound(path)) => {
                write_http(&mut stream, 404, &error_body(&format!("no such endpoint {path}")), close)?;
            }
            Err(HttpError::Bad(msg)) => {
                write_http(&mut stream, 400, &error_body(&msg), close)?;
            }
        }
        if close {
            return Ok(());
        }
    }
}

/// Read one request head; `None` on a clean EOF.
fn read_http_request(
    reader: &mut BufReader<TcpStream>,
) -> io::Result<Option<(String, String, bool)>> {
    let mut line = String::new();
    if reader.read_line(&mut line)? == 0 {
        return Ok(None);
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let target = parts.next().unwrap_or("/").to_string();
    let mut close = false;
    loop {
        let mut header = String::new();
        if reader.read_line(&mut header)? == 0 {
            return Ok(None); // truncated head
        }
        let header = header.trim_end();
        if header.is_empty() {
            break;
        }
        if let Some((name, value)) = header.split_once(':') {
            if name.eq_ignore_ascii_case("connection") && value.trim().eq_ignore_ascii_case("close")
            {
                close = true;
            }
        }
    }
    Ok(Some((method, target, close)))
}

fn http_request(method: &str, target: &str) -> Result<Request, HttpError> {
    if method != "GET" {
        return Err(HttpError::Bad(format!("unsupported method {method:?}; all endpoints are GET")));
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };
    let req = match path {
        "/snapshot" => Request::Snapshot,
        "/aggregate" => Request::Aggregate,
        "/subscribe" => Request::Subscribe,
        "/top_k_worst" => Request::TopK(parse_param(query, "k")?),
        "/count_below" => Request::CountBelow(parse_param(query, "t")?),
        "/auc_histogram" => Request::AucHistogram(parse_param(query, "bins")?),
        "/score_histogram" => Request::ScoreHistogram(parse_param(query, "bins")?),
        other => return Err(HttpError::NotFound(other.to_string())),
    };
    validate(&req).map_err(HttpError::Bad)?;
    Ok(req)
}

fn parse_param<T: std::str::FromStr>(query: &str, name: &str) -> Result<T, HttpError>
where
    T::Err: std::fmt::Display,
{
    let raw = query
        .split('&')
        .find_map(|kv| kv.strip_prefix(name).and_then(|rest| rest.strip_prefix('=')))
        .ok_or_else(|| HttpError::Bad(format!("missing query parameter {name}")))?;
    raw.parse()
        .map_err(|e| HttpError::Bad(format!("query parameter {name}={raw}: {e}")))
}

fn subscribe_http(mut stream: TcpStream, shared: &Shared) -> io::Result<()> {
    let st = shared.pub_state.lock().expect("publisher state");
    let line = json::sketch_to_json(st.seq, &st.last);
    // Streaming body: no Content-Length, the connection is the frame.
    stream.write_all(
        b"HTTP/1.1 200 OK\r\nContent-Type: application/x-ndjson\r\nConnection: close\r\n\r\n",
    )?;
    stream.write_all(line.as_bytes())?;
    stream.write_all(b"\n")?;
    shared
        .subs
        .lock()
        .expect("subscriber list")
        .push(Subscriber { stream, proto: Proto::Http });
    drop(st);
    Ok(())
}

fn error_body(msg: &str) -> String {
    use std::fmt::Write as _;
    let mut out = String::with_capacity(msg.len() + 16);
    out.push_str("{\"error\":\"");
    for ch in msg.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push_str("\"}");
    out
}

fn write_http(stream: &mut TcpStream, status: u16, body: &str, close: bool) -> io::Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        _ => "Error",
    };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
        body.len(),
        if close { "close" } else { "keep-alive" }
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())
}
