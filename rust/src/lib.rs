//! # streamauc — efficient estimation of AUC in a sliding window
//!
//! Rust + JAX/Pallas reproduction of *“Efficient estimation of AUC in a
//! sliding window”* (Nikolaj Tatti, ECML-PKDD 2019).
//!
//! The crate maintains an `ε/2`-approximate area under the ROC curve over a
//! sliding window of `(score, label)` pairs in `O((log k)/ε)` time per
//! update, versus `O(k)` for exact recomputation. The estimator groups
//! neighbouring score nodes into a `(1+ε)`-*compressed* weighted linked
//! list (paper Eqs. 3–4) built on top of an augmented red-black tree.
//! Beyond the paper, *reading* the estimate is `O(1)`: the doubled-area
//! accumulator is maintained incrementally in integer arithmetic,
//! bit-identical to the paper's `O(|C|)` scan (`rust/DESIGN.md`
//! §Incremental-reads).
//!
//! ## Layer map
//!
//! * [`collections`] — the supporting data structures of paper §3:
//!   augmented red-black tree (`T`, `TP`) and weighted linked lists
//!   (`P`, `C`), both backed by typed slab arenas
//!   (`collections/arena.rs`): nodes and cells are `u32`-indexed slots
//!   in pools a *shard* can own, so a million streams share free lists
//!   instead of each pinning peak-capacity `Vec`s (`rust/DESIGN.md`
//!   §Memory).
//! * [`coordinator`] — the estimators of paper §4 (approximate — with
//!   the incremental `O(1)` read, `coordinator/approx.rs` — exact
//!   baseline, naive oracle, flipped variant, §7 weighted extension,
//!   and the delta-maintained exact estimator
//!   [`MaintainedExactAuc`] in `coordinator/maintained.rs`: `O(log k)`
//!   update, `O(1)` read, zero approximation — plus the bounded-score
//!   fast path [`BinnedAuc`] in `coordinator/binned.rs`: fixed cells
//!   over a declared `[lo, hi]` range, no tree at all, with a derived
//!   discretization bound — and the H-measure
//!   coherent alternative in `coordinator/metrics.rs`), the
//!   sliding-window driver, drift monitor and metrics.
//! * [`fleet`] — the multi-stream service layer: an [`AucFleet`] of
//!   thousands of independent sliding windows keyed by stream id.
//!   Streams pick their estimator per
//!   [`EstimatorKind`](fleet::EstimatorKind) — the paper's
//!   `ε`-approximate sketch, the maintained exact accumulator, or the
//!   binned bounded-score fast path (auto-selected from a declared
//!   score range via [`StreamConfig::auto`](fleet::StreamConfig::auto))
//!   — and all kinds coexist in one fleet. Each
//!   shard owns its slab of stream states outright (`Send`-clean from
//!   the rbtree up); every fleet operation — batched ingestion *and*
//!   the read paths (aggregates, snapshots, queries, eviction) — runs
//!   as a typed shard job (`fleet/pool.rs`) work-stealing on a
//!   persistent worker pool (spawned once, parked between jobs,
//!   optionally pipelining the next batch while the previous drains,
//!   optionally scaling active workers to the batch size) with results
//!   bit-identical to serial under every strategy — the contract
//!   `rust/tests/executor.rs` attacks with adversarial schedules.
//!   Each shard maintains a running sketch of its streams' estimates
//!   (`fleet/shard.rs`), so fleet aggregates and the `fleet/query.rs`
//!   monitoring queries (worst-k triage, threshold counts, AUC
//!   histograms, predicate scans) answer from `O(shards·bins)` merges
//!   plus candidate-bin refinement instead of per-stream rescans —
//!   bit-identical to the retained rescan reference; plus fleet-wide
//!   drift alarms, streaming snapshots, and idle- and age-based stream
//!   eviction. Between hot and evicted sits cold-stream hibernation
//!   (`fleet/frozen.rs`): `hibernate_idle` freezes idle windows into
//!   compact contiguous buffers — arena slots returned to the shard,
//!   estimate pinned, queries still answered — and the next push
//!   rehydrates bit-identically, so a stream that hibernated is
//!   indistinguishable digest-for-digest from one that never did;
//!   logical memory accounting (`footprint_bytes`) rides the sketches
//!   and both wire protocols (`rust/DESIGN.md` §Memory).
//! * [`serve`] — the fleet's query surface over the wire: a std-only
//!   [`FleetServer`](serve::FleetServer) speaking HTTP/1.1 (JSON) and a
//!   length-prefixed binary protocol on one `TcpListener` port, with
//!   every endpoint answering bit-identical to the in-process query at
//!   an echoed publication seq and a subscription stream pushing one
//!   fleet-sketch delta per ingestion drain. The front-end is bounded
//!   and deadline-driven: `serve/limits.rs` (worker pool sizing, the
//!   bounded accept queue that sheds overload with 503/`STATUS_BUSY`,
//!   socket timeouts + per-request deadline budgets, the
//!   live-connection tracker that makes shutdown a real drain) and
//!   `serve/publish.rs` (epoch-swapped
//!   [`PublishedView`](serve::PublishedView)s serving sketch-answerable
//!   reads without the fleet lock, plus per-subscriber bounded queues
//!   with a lag-coalescing resync so no stuck client stalls ingestion)
//!   (`rust/DESIGN.md` §Serving).
//! * [`stream`] — deterministic synthetic data sources standing in for the
//!   paper's UCI datasets (see `DESIGN.md` §Substitutions), the
//!   multi-stream fleet generator, drift injectors and CSV I/O.
//! * [`runtime`] — PJRT execution of the AOT-compiled JAX/Pallas
//!   logistic-regression classifier (`artifacts/*.hlo.txt`): training loop
//!   and batch scorer. Python never runs on the streaming path.
//! * [`experiments`] — drivers regenerating every table and figure of the
//!   paper's §6 evaluation.
//!
//! ## Quickstart
//!
//! ```
//! use streamauc::coordinator::SlidingAuc;
//!
//! let mut w = SlidingAuc::new(1000, 0.01); // window k=1000, ε=0.01
//! for i in 0..5000u32 {
//!     let label = i % 3 == 0;
//!     let score = if label { 0.3 } else { 0.7 } + 0.01 * f64::from(i % 100);
//!     w.push(score, label);
//! }
//! let auc = w.auc();
//! assert!(auc > 0.5 && auc <= 1.0);
//! ```
//!
//! At service scale, maintain many windows at once through the fleet
//! layer:
//!
//! ```
//! use streamauc::fleet::AucFleet;
//!
//! let mut fleet = AucFleet::with_defaults();
//! fleet.push_batch(&[(7, 0.2, true), (7, 0.8, false), (9, 0.4, true)]);
//! assert_eq!(fleet.stream_count(), 2);
//! assert_eq!(fleet.auc(7), Some(1.0));
//! ```

pub mod cli;
pub mod collections;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod fleet;
pub mod runtime;
pub mod serve;
pub mod stream;
pub mod testing;

pub use coordinator::{
    ApproxAuc, AucEstimator, BinnedAuc, ExactAuc, MaintainedExactAuc, SlidingAuc,
};
pub use fleet::AucFleet;
pub use serve::FleetServer;
