//! Drivers regenerating the paper's evaluation (§6).
//!
//! One driver per table/figure, each returning a [`report::Table`] whose
//! rows mirror what the paper plots:
//!
//! | id | paper artefact | driver |
//! |----|----------------|--------|
//! | Table 1 | dataset characteristics | [`table1::run`] |
//! | Fig 1 | avg & max relative error vs ε (k = 1000) | [`fig1::run`] |
//! | Fig 2 | runtime and `\|C\|` vs avg error (k = 1000) | [`fig2::run`] |
//! | Fig 3 | runtime vs window size, exact vs ε ∈ {0.01, 0.1} | [`fig3::run`] |
//!
//! Absolute times differ from the paper's 2019 MacBook Air; the *shapes*
//! (error ≪ ε/2, runtime plateau, speed-up growing with k) are the
//! reproduction targets. EXPERIMENTS.md records paper-vs-measured.

pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod report;
pub mod table1;

pub use report::Table;

/// Common experiment configuration.
#[derive(Clone, Copy, Debug)]
pub struct ExpConfig {
    /// Events per dataset stream (the paper streams full test sets; the
    /// default keeps a laptop-scale run under a minute per figure).
    pub events: usize,
    /// Sliding-window size `k` (the paper uses 1000 for Figs. 1–2).
    pub window: usize,
    /// Master seed.
    pub seed: u64,
}

impl Default for ExpConfig {
    fn default() -> Self {
        ExpConfig { events: 50_000, window: 1000, seed: 0xA0C_2019 }
    }
}

/// The ε grid shared by the Fig. 1 / Fig. 2 sweeps (the paper sweeps
/// roughly 10⁻⁴ … 1 on a log axis).
pub const EPSILONS: [f64; 9] = [1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1, 3e-1, 1.0];
