//! Shard-owned fleet state: the unit of parallelism.
//!
//! A [`Shard`] owns everything needed to ingest its slice of the fleet's
//! traffic without touching any other shard: the dense stream slab, the
//! stream-id → slot index, and a shard-local alarm log. Because the
//! state is fully shard-owned (no `Rc`, no interior mutability — see
//! the compile-time `Send` assertion at the bottom), each shard sits
//! behind its own mutex in the fleet core and is claimed by exactly one
//! worker of the work-stealing drain (`fleet/pool.rs`), so the locks
//! never contend. Batch buckets live fleet-side (`AucFleet` stages
//! them while the previous batch drains — the pipelining overlap) and
//! arrive here as plain slices; their *sizes* drive both the
//! precomputed tick stamps and the size-aware claim queue.
//!
//! Determinism contract: a shard's observable state after
//! [`Shard::drain_events`] depends only on the events it is given and
//! the `start_tick` — never on which thread ran it or when. Alarms
//! accumulate in the shard-local log and are merged into the
//! fleet-wide log in shard-index order, which is exactly the order the
//! serial path produces, so parallel and serial ingestion are
//! bit-identical (`rust/DESIGN.md` §Parallelism).

use std::collections::HashMap;

use crate::coordinator::window::Window;
use crate::coordinator::{ApproxAuc, AucMonitor, MonitorEvent};

use super::config::StreamConfig;
use super::snapshot::{FleetAlarm, StreamSnapshot};

/// One stream's state: sliding estimator window plus optional drift
/// monitor. Factored out of the shard so future per-stream features
/// (decay, flipped estimators) have one place to live.
#[derive(Clone, Debug)]
pub(super) struct StreamState {
    /// Stream id (also the key in the owning shard's index).
    pub(super) id: u64,
    /// The ε/2-approximate sliding window.
    pub(super) win: Window<ApproxAuc>,
    /// Drift monitor; `None` when monitoring is disabled for the stream.
    pub(super) monitor: Option<AucMonitor>,
    /// Stream-local events ingested over the stream's lifetime.
    pub(super) events: u64,
    /// Alarms raised over the stream's lifetime.
    pub(super) alarms: u32,
    /// Fleet-wide tick (total fleet event count) at this stream's most
    /// recent event; drives [`Shard::evict_idle`].
    pub(super) last_seen: u64,
}

impl StreamState {
    pub(super) fn new(id: u64, cfg: &StreamConfig) -> StreamState {
        StreamState {
            id,
            win: Window::with_estimator(cfg.window, ApproxAuc::new(cfg.epsilon)),
            monitor: cfg.monitor.map(|m| m.build()),
            events: 0,
            alarms: 0,
            last_seen: 0,
        }
    }

    /// Point-in-time snapshot of this stream.
    pub(super) fn snapshot(&self) -> StreamSnapshot {
        StreamSnapshot {
            stream: self.id,
            auc: self.win.auc(),
            len: self.win.len(),
            compressed_len: self.win.estimator().compressed_len(),
            events: self.events,
            alarms: self.alarms,
            alarmed: self.monitor.as_ref().map_or(false, AucMonitor::is_alarmed),
            baseline: self.monitor.as_ref().map(AucMonitor::baseline),
        }
    }
}

/// One shard: dense stream slab, id index and local alarm log. See the
/// module docs for the ownership/determinism rules.
#[derive(Clone, Debug, Default)]
pub(super) struct Shard {
    /// Dense slab of stream states (hot streams stay contiguous).
    streams: Vec<StreamState>,
    /// Stream id → slot in `streams`.
    index: HashMap<u64, u32>,
    /// Shard-local alarm log, merged into the fleet log in shard order.
    alarms: Vec<FleetAlarm>,
}

impl Shard {
    /// Number of live streams in this shard.
    pub(super) fn len(&self) -> usize {
        self.streams.len()
    }

    /// The stream slab (slot order: insertion order, perturbed only by
    /// [`Shard::evict_idle`] compaction).
    pub(super) fn streams(&self) -> &[StreamState] {
        &self.streams
    }

    /// Look up a stream by id.
    pub(super) fn get(&self, id: u64) -> Option<&StreamState> {
        self.index.get(&id).map(|&slot| &self.streams[slot as usize])
    }

    /// Slot of `id`, creating the stream on first contact with the
    /// override config if one is registered, the defaults otherwise.
    pub(super) fn ensure_slot(
        &mut self,
        id: u64,
        defaults: &StreamConfig,
        overrides: &HashMap<u64, StreamConfig>,
    ) -> usize {
        if let Some(&slot) = self.index.get(&id) {
            return slot as usize;
        }
        let cfg = overrides.get(&id).copied().unwrap_or(*defaults);
        let slot = self.streams.len();
        self.streams.push(StreamState::new(id, &cfg));
        self.index.insert(id, slot as u32);
        slot
    }

    /// Reset a live stream under a new configuration (window contents,
    /// monitor state and counters start fresh). Returns false when the
    /// stream is not live. `now` is the current fleet tick, recorded as
    /// the reset stream's `last_seen` so a reconfigure does not make it
    /// instantly eligible for idle eviction.
    pub(super) fn reset_stream(&mut self, id: u64, cfg: &StreamConfig, now: u64) -> bool {
        match self.index.get(&id) {
            Some(&slot) => {
                let mut st = StreamState::new(id, cfg);
                st.last_seen = now;
                self.streams[slot as usize] = st;
                true
            }
            None => false,
        }
    }

    /// Ingest one event into a resolved slot: window update plus monitor
    /// observation (only on full windows, so partially filled streams
    /// never alarm on warm-up noise). `tick` is the fleet-wide event
    /// number of this event (1-based).
    pub(super) fn push_at(&mut self, slot: usize, score: f64, label: bool, tick: u64) {
        let st = &mut self.streams[slot];
        st.win.push(score, label);
        st.events += 1;
        st.last_seen = tick;
        if st.win.is_full() {
            if let Some(m) = st.monitor.as_mut() {
                let auc = st.win.auc();
                if m.observe(auc) == MonitorEvent::Alarm {
                    st.alarms += 1;
                    self.alarms.push(FleetAlarm {
                        stream: st.id,
                        stream_event: st.events,
                        auc,
                        baseline: m.baseline(),
                    });
                }
            }
        }
    }

    /// Ingest one batch bucket in arrival order, resolving the
    /// stream-id → slot lookup once per run of same-stream events.
    /// Events are stamped with fleet ticks `start_tick + 1, + 2, …` —
    /// the exact ticks the serial shard-by-shard drain would assign,
    /// which is what makes out-of-order parallel draining deterministic.
    pub(super) fn drain_events(
        &mut self,
        events: &[(u64, f64, bool)],
        defaults: &StreamConfig,
        overrides: &HashMap<u64, StreamConfig>,
        start_tick: u64,
    ) {
        let mut tick = start_tick;
        let mut i = 0;
        while i < events.len() {
            let id = events[i].0;
            let mut j = i + 1;
            while j < events.len() && events[j].0 == id {
                j += 1;
            }
            let slot = self.ensure_slot(id, defaults, overrides);
            for &(_, score, label) in &events[i..j] {
                tick += 1;
                self.push_at(slot, score, label, tick);
            }
            i = j;
        }
    }

    /// Append this shard's pending alarms to `out` (emptying the local
    /// log). Called in shard-index order by the fleet after every
    /// ingestion step, which fixes the fleet-wide alarm order.
    pub(super) fn take_alarms_into(&mut self, out: &mut Vec<FleetAlarm>) {
        out.append(&mut self.alarms);
    }

    /// Drop streams idle for at least `max_idle` fleet ticks (`now` is
    /// the current fleet tick), compacting the slab via swap-remove and
    /// repairing the index. Returns the number of evicted streams.
    pub(super) fn evict_idle(&mut self, now: u64, max_idle: u64) -> usize {
        let mut evicted = 0;
        let mut slot = 0;
        while slot < self.streams.len() {
            if now.saturating_sub(self.streams[slot].last_seen) >= max_idle {
                let dead = self.streams.swap_remove(slot);
                self.index.remove(&dead.id);
                if let Some(moved) = self.streams.get(slot) {
                    self.index.insert(moved.id, slot as u32);
                }
                evicted += 1;
            } else {
                slot += 1;
            }
        }
        evicted
    }
}

// Shards cross thread boundaries (pool workers lock and drain them);
// this compiles only while every constituent (rbtree arena, weighted
// lists, window FIFO, monitor) stays free of `Rc`/interior mutability.
const _: () = {
    const fn assert_send<T: Send>() {}
    assert_send::<StreamState>();
    assert_send::<Shard>();
};
