//! Fleet integration: 200 streams × ~5k events each with per-stream
//! drift, spot-checked against freshly built naive oracles over the
//! identical window contents, with alarm coverage assertions.
//!
//! The event soup comes from the bursty [`MultiStream`] generator;
//! streams 0..20 break abruptly halfway through their traffic. The
//! fleet maintains one ε/2-approximate window + drift monitor per
//! stream, with a handful of streams running on per-stream config
//! overrides (tighter ε, smaller window).

use std::collections::HashSet;

use streamauc::coordinator::NaiveAuc;
use streamauc::fleet::{AucFleet, FleetConfig, MonitorConfig, StreamConfig};
use streamauc::stream::{DriftSchedule, MultiStream, Pcg, StreamProfile};

const STREAMS: u64 = 200;
const DRIFTED: u64 = 20;
const EVENTS: usize = 1_000_000; // ≈ 5k events per stream
const BATCH: usize = 4_096;
const DEFAULT_EPS: f64 = 0.2;
const OVERRIDE_EPS: f64 = 0.05;
/// Streams 190..200 run with the tighter override config.
const OVERRIDE_FROM: u64 = 190;

fn build_fleet() -> AucFleet {
    let mut fleet = AucFleet::new(FleetConfig {
        shards: 32,
        stream_defaults: StreamConfig {
            window: 200,
            epsilon: DEFAULT_EPS,
            monitor: Some(MonitorConfig {
                lambda: 0.001,
                margin: 0.08,
                patience: 50,
                warmup: 250,
            }),
        },
    });
    for id in OVERRIDE_FROM..STREAMS {
        fleet.configure_stream(id, StreamConfig::new(120, OVERRIDE_EPS));
    }
    fleet
}

fn build_generator() -> MultiStream {
    let per_stream = EVENTS as u64 / STREAMS; // ≈ 5000
    let profiles: Vec<StreamProfile> = (0..STREAMS)
        .map(|id| {
            let p = StreamProfile::healthy(id);
            if id < DRIFTED {
                p.with_drift(DriftSchedule::Abrupt { at: per_stream / 2, rate: 0.6 })
            } else {
                p
            }
        })
        .collect();
    MultiStream::with_profiles(profiles, 0x200_5000).with_mean_burst(8.0)
}

#[test]
fn fleet_200_streams_drift_and_differential_spot_checks() {
    let mut fleet = build_fleet();
    let mut gen = build_generator();

    let mut pushed = 0;
    while pushed < EVENTS {
        let n = BATCH.min(EVENTS - pushed);
        fleet.push_batch(&gen.next_batch(n));
        pushed += n;
    }
    assert_eq!(fleet.total_events(), EVENTS as u64);
    assert_eq!(fleet.stream_count(), STREAMS as usize, "every stream must be live");

    // ---- differential spot-checks: ≥20 random streams against a
    // freshly built naive oracle over the same window contents -------
    let mut rng = Pcg::seed(0x5707);
    let mut checked = HashSet::new();
    while checked.len() < 20 {
        checked.insert(rng.below(STREAMS));
    }
    // Always include override streams so both configs are exercised.
    checked.insert(OVERRIDE_FROM);
    checked.insert(STREAMS - 1);
    for &id in &checked {
        let window: Vec<(f64, bool)> = fleet.entries(id).expect("live stream").collect();
        let cfg = fleet.stream_config(id);
        assert!(!window.is_empty() && window.len() <= cfg.window, "stream {id} window size");
        let truth = NaiveAuc::of(&window);
        let est = fleet.auc(id).expect("live stream");
        assert!(
            (est - truth).abs() <= cfg.epsilon * truth / 2.0 + 1e-12,
            "stream {id} (ε = {}): est {est} vs naive {truth}",
            cfg.epsilon
        );
    }

    // ---- alarms fire on the drifted streams, and only there --------
    let alarmed: HashSet<u64> = fleet.alarms().iter().map(|a| a.stream).collect();
    for id in 0..DRIFTED {
        assert!(alarmed.contains(&id), "drifted stream {id} never alarmed");
    }
    for &id in &alarmed {
        assert!(id < DRIFTED, "healthy stream {id} raised a false alarm");
    }
    // Drifted streams are still degraded at end-of-stream, so the
    // snapshot must report them as currently alarmed.
    let snap = fleet.snapshot();
    let snap_alarmed: HashSet<u64> = snap.alarmed_streams.iter().copied().collect();
    for id in 0..DRIFTED {
        assert!(snap_alarmed.contains(&id), "stream {id} not alarmed in snapshot");
    }

    // ---- snapshot-level health separation --------------------------
    let (mut drifted_auc, mut healthy_auc) = (0.0, 0.0);
    for s in &snap.streams {
        if s.stream < DRIFTED {
            drifted_auc += s.auc;
        } else {
            healthy_auc += s.auc;
        }
    }
    drifted_auc /= DRIFTED as f64;
    healthy_auc /= (STREAMS - DRIFTED) as f64;
    assert!(healthy_auc > 0.85, "healthy fleet mean AUC {healthy_auc}");
    assert!(drifted_auc < 0.6, "drifted fleet mean AUC {drifted_auc} should collapse");
    assert!(
        snap.streams.iter().all(|s| s.events > 3_000),
        "bursty scheduling starved a stream"
    );

    // Alarm records carry consistent metadata.
    for a in fleet.alarms() {
        assert!(a.auc < a.baseline - 0.08 + 1e-9, "alarm without margin violation");
        assert!(a.stream_event > 200, "alarm before the window ever filled");
    }
}
