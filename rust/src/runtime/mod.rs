//! PJRT runtime: load and execute the AOT-compiled classifier.
//!
//! The JAX/Pallas model (python/compile) is lowered once to HLO *text*
//! (`make artifacts`); this module loads those artifacts into a PJRT CPU
//! client and drives them from rust — training loop and batch scorer —
//! so Python never runs on the streaming path.
//!
//! * [`meta`] — minimal JSON parsing for `artifacts/meta.json` (the
//!   shape contract; serde is unavailable offline).
//! * [`client`] — PJRT client + HLO-text loading.
//! * [`executable`] — typed execute helpers over `xla::Literal`s.
//! * [`trainer`] — minibatch SGD through the `train_step` artifact.
//! * [`scorer`] — batched scoring through the `score_batch` artifact.

pub mod client;
pub mod executable;
pub mod meta;
pub mod scorer;
pub mod trainer;

pub use client::Runtime;
pub use meta::Meta;
pub use scorer::Scorer;
pub use trainer::{TrainReport, Trainer};
