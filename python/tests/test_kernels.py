"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes (batch heights around the tile boundary, several
feature widths) and dtypes, asserting allclose against ``ref.py``. These
are the core correctness signal for the kernel layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is unavailable in some offline environments; these sweeps
# are advisory (the rust layer carries its own differential suite), so
# skip the module rather than fail collection.
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import logreg, ref

F32_TOL = dict(rtol=1e-5, atol=1e-5)
BF16_TOL = dict(rtol=2e-2, atol=2e-2)


def draw_case(seed, batch, dims, dtype=jnp.float32, scale=1.0):
    k = jax.random.PRNGKey(seed)
    kw, kb, kx, ky = jax.random.split(k, 4)
    w = (jax.random.normal(kw, (dims,)) * scale).astype(dtype)
    b = (jax.random.normal(kb, ()) * scale).astype(dtype)
    x = jax.random.normal(kx, (batch, dims)).astype(dtype)
    y = jax.random.bernoulli(ky, 0.4, (batch,)).astype(dtype)
    return w, b, x, y


# ---------------------------------------------------------------- score

@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    batch=st.sampled_from([1, 3, 64, 128, 256, 384, 1024]),
    dims=st.sampled_from([4, 28, 50, 124, 128, 256]),
)
def test_score_matches_ref_shapes(seed, batch, dims):
    w, b, x, _ = draw_case(seed, batch, dims)
    got = logreg.score_batch(w, b, x)
    want = ref.score_batch(w, b, x)
    assert got.shape == (batch,)
    np.testing.assert_allclose(got, want, **F32_TOL)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_score_matches_ref_bf16(seed):
    w, b, x, _ = draw_case(seed, 128, 128, dtype=jnp.bfloat16)
    got = logreg.score_batch(w, b, x).astype(jnp.float32)
    want = ref.score_batch(
        w.astype(jnp.float32), b.astype(jnp.float32), x.astype(jnp.float32)
    )
    np.testing.assert_allclose(got, want, **BF16_TOL)


def test_score_explicit_block_sizes():
    w, b, x, _ = draw_case(7, 512, 64)
    want = ref.score_batch(w, b, x)
    for blk in [32, 64, 128, 256, 512]:
        got = logreg.score_batch(w, b, x, block_b=blk)
        np.testing.assert_allclose(got, want, **F32_TOL)


def test_score_extreme_logits_saturate_cleanly():
    w = jnp.full((8,), 50.0, jnp.float32)
    b = jnp.zeros((), jnp.float32)
    x = jnp.stack([jnp.ones((8,)), -jnp.ones((8,))]).astype(jnp.float32)
    got = logreg.score_batch(w, b, x)
    np.testing.assert_allclose(got, jnp.array([1.0, 0.0]), atol=1e-6)
    assert bool(jnp.all(jnp.isfinite(got)))


def test_scores_are_probabilities():
    w, b, x, _ = draw_case(3, 256, 128, scale=3.0)
    got = logreg.score_batch(w, b, x)
    assert bool(jnp.all((got >= 0) & (got <= 1)))


# ----------------------------------------------------------------- grad

@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    batch=st.sampled_from([1, 64, 128, 256, 512]),
    dims=st.sampled_from([4, 28, 128]),
)
def test_grad_partials_match_ref(seed, batch, dims):
    w, b, x, y = draw_case(seed, batch, dims)
    gw_parts, gb_parts = logreg.grad_partials(w, b, x, y)
    gw = jnp.sum(gw_parts, axis=0) / batch
    gb = jnp.sum(gb_parts) / batch
    want_gw, want_gb = ref.grad(w, b, x, y)
    np.testing.assert_allclose(gw, want_gw, **F32_TOL)
    np.testing.assert_allclose(gb, want_gb, **F32_TOL)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_grad_matches_autodiff(seed):
    """Kernel gradient equals jax.grad of the reference loss."""
    w, b, x, y = draw_case(seed, 128, 32)
    gw_parts, gb_parts = logreg.grad_partials(w, b, x, y)
    gw = jnp.sum(gw_parts, axis=0) / x.shape[0]
    gb = jnp.sum(gb_parts) / x.shape[0]
    a_gw, a_gb = jax.grad(ref.mean_logloss, argnums=(0, 1))(w, b, x, y)
    np.testing.assert_allclose(gw, a_gw, **F32_TOL)
    np.testing.assert_allclose(gb, a_gb, **F32_TOL)


def test_grad_zero_at_optimum_of_separable_flat():
    """Residual (p − y) is zero when p == y exactly."""
    dims = 16
    w = jnp.zeros((dims,), jnp.float32)
    b = jnp.zeros((), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(0), (64, dims), jnp.float32)
    y = jnp.full((64,), 0.5, jnp.float32)  # p = 0.5 = y → zero grad
    gw_parts, gb_parts = logreg.grad_partials(w, b, x, y)
    np.testing.assert_allclose(jnp.sum(gw_parts, axis=0), jnp.zeros(dims), atol=1e-5)
    np.testing.assert_allclose(jnp.sum(gb_parts), 0.0, atol=1e-5)


def test_grad_tile_partials_sum_invariant():
    """Partials with different tilings sum to the same gradient."""
    w, b, x, y = draw_case(11, 512, 64)
    sums = []
    for blk in [64, 128, 512]:
        gw_parts, gb_parts = logreg.grad_partials(w, b, x, y, block_b=blk)
        assert gw_parts.shape == (512 // blk, 64)
        sums.append(
            (jnp.sum(gw_parts, axis=0), jnp.sum(gb_parts))
        )
    for gw, gb in sums[1:]:
        np.testing.assert_allclose(gw, sums[0][0], **F32_TOL)
        np.testing.assert_allclose(gb, sums[0][1], **F32_TOL)


def test_indivisible_batch_falls_back_to_single_tile():
    w, b, x, y = draw_case(5, 130, 16)  # 130 % 128 != 0
    got = logreg.score_batch(w, b, x)
    np.testing.assert_allclose(got, ref.score_batch(w, b, x), **F32_TOL)
    gw_parts, _ = logreg.grad_partials(w, b, x, y)
    assert gw_parts.shape[0] == 1


def test_kernels_are_jittable_end_to_end():
    """The kernels must lower inside a jitted caller (the L2 path)."""

    @jax.jit
    def pipeline(w, b, x):
        return logreg.score_batch(w, b, x) * 2.0

    w, b, x, _ = draw_case(13, 128, 128)
    np.testing.assert_allclose(
        pipeline(w, b, x), ref.score_batch(w, b, x) * 2.0, **F32_TOL
    )
