//! Result tables: aligned console output + CSV persistence.
//!
//! Every experiment driver returns a [`Table`]; the CLI prints it and
//! optionally writes the CSV next to the run, so paper figures can be
//! regenerated from the artifacts.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

use anyhow::{Context, Result};

/// A rectangular result table.
#[derive(Clone, Debug)]
pub struct Table {
    /// Table title (experiment id, e.g. `"fig1"`).
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Rows (already formatted cells).
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// Empty table with headers.
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match the header count).
    pub fn push(&mut self, row: Vec<String>) {
        assert_eq!(row.len(), self.headers.len(), "row width mismatch");
        self.rows.push(row);
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |cells: &[String], widths: &[usize]| {
            let mut s = String::new();
            for (i, cell) in cells.iter().enumerate() {
                if i > 0 {
                    s.push_str("  ");
                }
                let _ = write!(s, "{:>width$}", cell, width = widths[i]);
            }
            s
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len() - 1);
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    /// Write as CSV.
    pub fn write_csv(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        writeln!(f, "{}", self.headers.join(","))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join(","))?;
        }
        Ok(())
    }
}

/// Format a duration in adaptive units (ns/µs/ms/s).
pub fn fmt_duration(d: std::time::Duration) -> String {
    let ns = d.as_nanos();
    if ns < 10_000 {
        format!("{ns}ns")
    } else if ns < 10_000_000 {
        format!("{:.1}µs", ns as f64 / 1e3)
    } else if ns < 10_000_000_000 {
        format!("{:.1}ms", ns as f64 / 1e6)
    } else {
        format!("{:.2}s", ns as f64 / 1e9)
    }
}

/// Format a float in compact scientific-ish form for tables.
pub fn fmt_sci(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.abs() >= 0.01 && x.abs() < 10_000.0 {
        format!("{x:.4}")
    } else {
        format!("{x:.2e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new("demo", &["a", "long_header", "c"]);
        t.push(vec!["1".into(), "2".into(), "3".into()]);
        t.push(vec!["100".into(), "x".into(), "yy".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        let lines: Vec<&str> = s.lines().collect();
        // All data lines have equal length.
        assert_eq!(lines[1].len(), lines[3].len());
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_checked() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.push(vec!["1".into()]);
    }

    #[test]
    fn csv_roundtrip_format() {
        let dir = std::env::temp_dir().join("streamauc-report");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        let mut t = Table::new("demo", &["a", "b"]);
        t.push(vec!["1".into(), "2".into()]);
        t.write_csv(&path).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "a,b\n1,2\n");
    }

    #[test]
    fn duration_units() {
        assert_eq!(fmt_duration(Duration::from_nanos(500)), "500ns");
        assert_eq!(fmt_duration(Duration::from_micros(50)), "50.0µs");
        assert_eq!(fmt_duration(Duration::from_millis(50)), "50.0ms");
        assert_eq!(fmt_duration(Duration::from_secs(50)), "50.00s");
    }

    #[test]
    fn sci_formatting() {
        assert_eq!(fmt_sci(0.0), "0");
        assert_eq!(fmt_sci(0.1234), "0.1234");
        assert_eq!(fmt_sci(1.5e-6), "1.50e-6");
    }
}
