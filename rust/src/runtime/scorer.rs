//! Batched scoring through the `score_batch` artifact.
//!
//! Turns feature rows into classifier scores on the rust side of the
//! stack — the producer end of the paper's pipeline (“we first receive a
//! data point … we predict the missing label with a score”, §1). Rows
//! are zero-padded to the artifact's feature width and scored in batches
//! of `meta.score_batch`, with a short final batch padded and truncated.

use anyhow::{ensure, Context, Result};

use super::executable::{features_literal, Executable};
use super::trainer::Params;
use super::Runtime;

/// Batch scorer bound to the `score_batch` artifact and fixed params.
pub struct Scorer {
    exec: Executable,
    params: Params,
    dims: usize,
    batch: usize,
}

impl Scorer {
    /// Load the `score_batch` artifact and bind trained parameters.
    pub fn new(rt: &Runtime, params: Params) -> Result<Scorer> {
        let meta = rt.meta();
        ensure!(
            params.w.len() == meta.dims,
            "params width {} != model dims {}",
            params.w.len(),
            meta.dims
        );
        let exec = rt.load("score_batch").context("load score_batch artifact")?;
        Ok(Scorer { exec, params, dims: meta.dims, batch: meta.score_batch })
    }

    /// Scoring batch size frozen into the artifact.
    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Score arbitrary-length feature rows (internally batched). Scores
    /// follow the paper's convention: larger ⇒ more likely negative.
    pub fn score(&self, rows: &[Vec<f32>]) -> Result<Vec<f64>> {
        let mut out = Vec::with_capacity(rows.len());
        for chunk in rows.chunks(self.batch) {
            let x = features_literal(chunk, self.batch, self.dims)?;
            let w = xla::Literal::vec1(&self.params.w);
            let b = xla::Literal::scalar(self.params.b);
            let result = self.exec.run_f32(&[w, b, x])?;
            ensure!(result.len() == 1, "score_batch must return (scores,)");
            out.extend(result[0][..chunk.len()].iter().map(|&s| f64::from(s)));
        }
        Ok(out)
    }
}

impl std::fmt::Debug for Scorer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Scorer")
            .field("dims", &self.dims)
            .field("batch", &self.batch)
            .finish_non_exhaustive()
    }
}
